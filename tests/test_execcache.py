"""Zero-stall produce path: megabatching, overlap, and compile discipline.

Three invariants of the rebuilt hot path:

* **Bitwise identity** — megabatched launches (K partitions, one dispatch)
  and the double-buffered ``produce_stream`` deliver exactly the bytes K
  solo ``produce_batch`` calls deliver, with the process-wide executable
  registry on and off.
* **Compile-count discipline** — concurrent pool workers on one engine
  trigger exactly ONE compile per shape, and independently built engines
  with equal cache signatures share ONE executable through
  ``core.execcache.EXECUTABLES`` instead of recompiling per engine.
* **Safety** — a lowered plan with a non-row-local stage refuses to
  megabatch rather than silently diverge.
"""

import threading
import time

import numpy as np
import pytest

from repro.configs.registry import get_recsys
from repro.core.execcache import EXECUTABLES, ExecKey, ExecutableCache
from repro.core.presto import PreStoEngine
from repro.core.service import JobSpec, PreprocessingService
from repro.core.spec import TransformSpec
from repro.data.loader import PrefetchLoader, WorkQueue
from repro.data.storage import PartitionedStore
from repro.data.synth import SyntheticRecSysSource


def _fixture(rows=256, partitions=12, rm="rm1"):
    rcfg = get_recsys(rm, reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=rows)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(partitions, num_devices=4, source=src)
    return spec, store


def _assert_bitwise(ref, got):
    assert sorted(got) == sorted(ref)
    for pid in ref:
        for key in ref[pid]:
            np.testing.assert_array_equal(
                np.asarray(ref[pid][key]), np.asarray(got[pid][key]),
                err_msg=f"pid={pid} key={key}",
            )


# -- megabatched execution ----------------------------------------------------


@pytest.mark.parametrize("placement", ["presto", "hybrid"])
def test_produce_batches_bitwise_identical_to_solo(placement):
    spec, store = _fixture(partitions=6)
    engine = PreStoEngine(spec, placement=placement)
    solo = {pid: engine.produce_batch(store, pid) for pid in range(6)}
    mega = dict(zip(range(6), engine.produce_batches(store, range(6))))
    _assert_bitwise(solo, mega)


@pytest.mark.parametrize("megabatch,overlap", [(1, True), (3, True), (4, False), (5, True)])
def test_produce_stream_bitwise_with_remainder_chunks(megabatch, overlap):
    """The double-buffered stream (any K, including non-dividing Ks whose
    last chunk is a remainder) delivers the serial loop's exact bytes in
    pid order."""
    spec, store = _fixture(partitions=10)
    engine = PreStoEngine(spec)
    solo = {pid: engine.produce_batch(store, pid) for pid in range(10)}
    out = list(engine.produce_stream(store, range(10), megabatch=megabatch,
                                     overlap=overlap))
    assert [pid for pid, _ in out] == list(range(10))  # delivery order kept
    _assert_bitwise(solo, dict(out))


def test_produce_stream_bitwise_without_exec_cache():
    """Registry off: a private-compile engine produces the same bytes."""
    spec, store = _fixture(partitions=4)
    shared = PreStoEngine(spec)
    private = PreStoEngine(spec, use_exec_cache=False)
    _assert_bitwise(
        {pid: shared.produce_batch(store, pid) for pid in range(4)},
        dict(private.produce_stream(store, range(4), megabatch=2)),
    )


def test_megabatch_refuses_non_row_local_stage(monkeypatch):
    """A stage kind outside kernels.ROW_LOCAL_KINDS must refuse to megabatch
    (stacking rows would not be bitwise-equal for cross-row operators)."""
    spec, store = _fixture(partitions=2)
    engine = PreStoEngine(spec)
    plan = engine.lowered_plan
    assert plan.megabatch_safe()
    monkeypatch.setattr(plan.stages[0], "kind", "batchnorm.partition")
    assert not plan.megabatch_safe()
    with pytest.raises(AssertionError, match="row-local"):
        engine.preprocess_megabatch(engine.stage_megabatch(store, [0, 1]))
    # the produce surfaces degrade gracefully to solo launches instead
    assert len(engine.produce_batches(store, [0, 1])) == 2
    assert [p for p, _ in
            engine.produce_stream(store, [0, 1], megabatch=2)] == [0, 1]


# -- the shared executable registry -------------------------------------------


def _unique_spec(rows: int, embedding_bump: int):
    """A Transform whose cache signature no other test shares (the spec
    digest covers table sizes, so bumping embedding_rows gives this test a
    private registry key without changing page/batch geometry)."""
    import dataclasses

    rcfg = get_recsys("rm1", reduced=True)
    cfg = dataclasses.replace(
        rcfg.data, embedding_rows=rcfg.data.embedding_rows + embedding_bump
    )
    src = SyntheticRecSysSource(cfg, rows=rows)
    return TransformSpec.from_source(src), src


def test_equal_signature_engines_share_one_executable():
    spec, _store = _fixture(rows=128, partitions=2)
    e1 = PreStoEngine(spec)
    e2 = PreStoEngine(spec)  # independently built, equal signature
    assert e1.cache_signature() == e2.cache_signature()
    assert e1.jit_preprocess_cached() is e2.jit_preprocess_cached()
    assert e1.jit_preprocess_megabatch_cached() is e2.jit_preprocess_megabatch_cached()
    # independently built from an EQUAL spec (the multi-tenant norm: each
    # tenant constructs its own) still shares
    spec_twin, _ = _fixture(rows=128, partitions=2)
    assert PreStoEngine(spec_twin).jit_preprocess_cached() is e1.jit_preprocess_cached()
    # a different Transform must NOT share
    spec3, _src = _unique_spec(rows=128, embedding_bump=3)
    e3 = PreStoEngine(spec3)
    assert e3.jit_preprocess_cached() is not e1.jit_preprocess_cached()
    # opting out compiles privately
    e4 = PreStoEngine(spec, use_exec_cache=False)
    assert e4.jit_preprocess_cached() is not e1.jit_preprocess_cached()


def test_concurrent_workers_one_engine_exactly_one_compile():
    """Compile-count discipline: a pool of workers hammering one engine's
    ``jit_preprocess_cached`` traces exactly once per shape."""
    rows = 320
    spec, src = _unique_spec(rows=rows, embedding_bump=7)
    store = PartitionedStore(12, num_devices=4, source=src)
    engine = PreStoEngine(spec)
    key = ExecKey(engine.cache_signature(), "solo", None)
    assert EXECUTABLES.trace_count(key) == 0

    with PreprocessingService(num_workers=4) as svc:
        session = svc.submit(JobSpec(
            name="compile-discipline", partitions=range(12), engine=engine,
            store=store, units=4))
        assert sorted(pid for pid, _ in session) == list(range(12))

    traces = EXECUTABLES.traces(key)
    assert traces == [{"k": 1, "rows": rows}], (
        f"expected exactly one compile for {rows}-row solo shape, "
        f"saw {traces}")
    # a second engine with the same signature reuses it: still one compile
    e2 = PreStoEngine(spec)
    e2.produce_batch(store, 0)
    assert EXECUTABLES.trace_count(key) == 1


def test_megabatch_shapes_compile_once_each():
    rows = 384
    spec, src = _unique_spec(rows=rows, embedding_bump=13)
    store = PartitionedStore(8, num_devices=4, source=src)
    engine = PreStoEngine(spec)
    key = ExecKey(engine.cache_signature(), "mega", None)

    engine.produce_batches(store, range(4))
    engine.produce_batches(store, range(4, 8))  # same K: no retrace
    assert EXECUTABLES.traces(key) == [{"k": 4, "rows": rows}]
    engine.produce_batches(store, range(2))  # new K: one more
    assert EXECUTABLES.trace_count(key) == 2


def test_registry_clear_and_stats_are_coherent():
    reg = ExecutableCache()
    key = ExecKey("sig", "solo", None)
    calls = []
    fn = reg.get_or_build(key, lambda: lambda pages: calls.append(1))
    assert reg.get_or_build(key, lambda: None) is fn
    assert reg.stats()["entries"] == 1 and reg.stats()["hits"] == 1
    assert reg.stats()["builds"] == 1
    reg.clear()
    assert reg.stats() == {"entries": 0, "hits": 0, "builds": 0, "traces": 0}


# -- service-level megabatching -----------------------------------------------


def test_service_megabatch_session_bitwise_and_complete():
    spec, store = _fixture(partitions=12)
    engine = PreStoEngine(spec)
    solo = {pid: engine.produce_batch(store, pid) for pid in range(12)}
    with PreprocessingService(num_workers=2) as svc:
        session = svc.submit(JobSpec(
            name="mega", partitions=range(12), engine=engine, store=store,
            units=2, megabatch=4, queue_depth=12))
        got = {pid: mb for pid, mb in session}
        st = session.stats()
    _assert_bitwise(solo, got)
    assert st.done and st.produced == 12 and st.duplicates_dropped == 0


def test_service_pipeline_off_still_bitwise():
    spec, store = _fixture(partitions=8)
    engine = PreStoEngine(spec)
    solo = {pid: engine.produce_batch(store, pid) for pid in range(8)}
    with PreprocessingService(num_workers=2, pipeline=False) as svc:
        session = svc.submit(JobSpec(
            name="legacy", partitions=range(8), engine=engine, store=store))
        got = {pid: mb for pid, mb in session}
    _assert_bitwise(solo, got)


def test_service_megabatch_with_device_fleet_charges_owners():
    """Megabatched produces still charge every partition's read to its
    OWNING device and route ops per claim — coalescing never blurs the
    per-device ledgers."""
    from repro.data.storage import DeviceFleet

    rcfg = get_recsys("rm1", reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=256)
    spec = TransformSpec.from_source(src)
    fleet = DeviceFleet(4)
    store = PartitionedStore(12, num_devices=4, source=src, fleet=fleet)
    plain_store = PartitionedStore(12, num_devices=4, source=src)
    engine = PreStoEngine(spec)
    solo = {pid: engine.produce_batch(plain_store, pid) for pid in range(12)}
    with PreprocessingService(num_workers=4, devices=fleet) as svc:
        session = svc.submit(JobSpec(
            name="mega-fleet", partitions=range(12), engine=engine,
            store=store, units=4, megabatch=3, queue_depth=12))
        got = {pid: mb for pid, mb in session}
        st = session.stats()
    _assert_bitwise(solo, got)
    assert st.done
    # every device owns 3 of the 12 round-robin partitions: all were read
    for dev in fleet:
        assert dev.bytes_streamed > 0
    produced_total = sum(st.device_produced.values()) + st.host_fallbacks
    assert produced_total == 12


# -- prefetch loader wakeups (satellite) --------------------------------------


def test_workqueue_next_deadline_tracks_earliest_claim():
    q = WorkQueue([0, 1], straggler_timeout=5.0)
    assert q.next_deadline() is None
    t0 = time.monotonic()
    q.claim()
    ddl = q.next_deadline()
    assert ddl is not None and 4.0 < ddl - t0 <= 5.1
    q.claim()
    assert q.next_deadline() == ddl  # earliest claim rules
    q.complete(0)
    q.complete(1)
    assert q.next_deadline() is None


def test_prefetch_loader_cv_delivers_all_with_slow_producer():
    """Idle workers sleep on the condition variable (no poll loop) yet still
    wake for straggler deadlines and completions: everything is delivered
    exactly once."""
    def produce(pid):
        if pid == 0:
            time.sleep(0.15)  # straggler: others must wake to re-issue it
        return pid * 10

    loader = PrefetchLoader(range(6), produce, num_workers=3, depth=2,
                            straggler_timeout=0.05)
    got = dict(loader)
    loader.stop()
    assert got == {pid: pid * 10 for pid in range(6)}
    assert loader.work.reissues >= 1  # the deadline wake actually fired


def test_prefetch_loader_stop_wakes_idle_workers_promptly():
    release = threading.Event()

    def produce(pid):
        if pid == 0:
            release.wait(0.5)  # hold one worker; the other goes idle
        return pid

    loader = PrefetchLoader([0, 1], produce, num_workers=2,
                            straggler_timeout=30.0).start()
    time.sleep(0.1)  # let the idle worker reach its long deadline wait
    t0 = time.perf_counter()
    loader.stop()  # must notify, not wait out the 30 s deadline
    release.set()
    assert time.perf_counter() - t0 < 3.0


def test_autotuned_sessions_share_mega_executables():
    """Autotuned sessions on independently built engines (equal cache
    signature) compile each tuner-visited megabatch shape exactly once
    through the shared registry — the climb explores the same power-of-two
    rungs, so the twin session adds ZERO new mega compiles."""
    rows = 448
    spec, src = _unique_spec(rows=rows, embedding_bump=17)
    store = PartitionedStore(12, num_devices=4, source=src)
    e1, e2 = PreStoEngine(spec), PreStoEngine(spec)
    key = ExecKey(e1.cache_signature(), "mega", None)
    assert EXECUTABLES.trace_count(key) == 0

    def run(engine):
        with PreprocessingService(num_workers=1) as svc:
            session = svc.submit(JobSpec(
                name="auto-share", partitions=range(12), engine=engine,
                store=store, units=1, queue_depth=12, autotune=True,
                megabatch=2, lookahead=2))
            return sorted(pid for pid, _ in session)

    assert run(e1) == list(range(12))
    # the K=2 ladder guarantees the climb measured both rungs: K=1 chunks
    # launch solo, K=2 chunks are the only mega shape
    assert EXECUTABLES.traces(key) == [{"k": 2, "rows": rows}]
    assert run(e2) == list(range(12))  # twin engine: no recompile
    assert EXECUTABLES.traces(key) == [{"k": 2, "rows": rows}]
