"""Serving-cache correctness: decode step must reproduce the train-time
forward logits position by position (prefill + incremental decode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.sharding import ShardingRules
from repro.models import transformer as T
from repro.models.config import ModelConfig

RULES = ShardingRules.make(None)

CASES = {
    "dense_full": ModelConfig(name="d", family="dense", n_layers=2, d_model=64,
                              n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                              dtype="float32", remat="none"),
    "dense_swa": ModelConfig(name="s", family="dense", n_layers=2, d_model=64,
                             n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                             attention="swa", window=16, dtype="float32",
                             remat="none"),
    "chunked": ModelConfig(name="c", family="dense", n_layers=4, d_model=64,
                           n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                           attention="chunked", chunk_size=16, dtype="float32",
                           remat="none"),
    "local_global": ModelConfig(name="lg", family="dense", n_layers=6, d_model=64,
                                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                                attention="local_global", local_global_period=6,
                                window=16, dtype="float32", remat="none"),
    "ssm": ModelConfig(name="m", family="ssm", n_layers=2, d_model=64, n_heads=1,
                       n_kv_heads=1, d_ff=0, vocab_size=128, ssm_state=16,
                       ssm_head_dim=16, ssm_chunk=16, dtype="float32",
                       remat="none"),
    "hybrid_moe": ModelConfig(name="h", family="hybrid", n_layers=8, d_model=64,
                              n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                              n_experts=4, top_k=2, moe_period=2, attn_period=8,
                              ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
                              capacity_factor=4.0, dtype="float32", remat="none"),
    "moe_top1": ModelConfig(name="m1", family="moe", n_layers=2, d_model=64,
                            n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=128,
                            n_experts=4, top_k=1, capacity_factor=4.0,
                            dtype="float32", remat="none"),
}


@pytest.mark.parametrize("case", list(CASES))
def test_decode_matches_forward(case, rng):
    cfg = CASES[case]
    S = 64
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, S)), jnp.int32)
    x = T._embed_tokens(params, toks, cfg, RULES)
    pos = jnp.broadcast_to(jnp.arange(S), (2, S))
    h, _ = T._backbone(params, x, pos, cfg, RULES)
    full_logits = T._logits_head(params, h, cfg, RULES)

    s0 = S // 2
    lg, caches = T.prefill(params, toks[:, :s0], cfg, RULES, S)
    errs = [float(jnp.abs(lg[:, 0] - full_logits[:, s0 - 1]).max())]
    dec = jax.jit(lambda p, t, c, n: T.decode_step(p, t, c, n, cfg, RULES))
    for t in range(s0, S):
        lg2, caches = dec(params, toks[:, t : t + 1], caches, jnp.int32(t))
        errs.append(float(jnp.abs(lg2[:, 0] - full_logits[:, t]).max()))
    assert max(errs) < 2e-2, (case, max(errs))
