"""End-to-end test of the dry-run driver itself: lowers + compiles a
REDUCED config on the real production meshes (256/512 fake devices) via the
CLI, and checks the emitted record has memory + roofline terms."""

import json
import os
import subprocess
import sys
import tempfile

import pytest


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_cli_reduced(mesh):
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "dryrun.jsonl")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "h2o-danube-1.8b", "--shape", "train_4k",
             "--mesh", mesh, "--reduced", "--out", out],
            capture_output=True, text=True, env=env, timeout=420,
        )
        assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
        rec = json.loads(open(out).read().splitlines()[0])
        assert rec["status"] == "ok"
        assert rec["chips"] == (512 if mesh == "multi" else 256)
        assert rec["memory"]["temp_bytes"] >= 0
        t = rec["roofline"]
        assert t["flops_per_dev"] > 0 and t["dominant"] in (
            "compute", "memory", "collective")
