"""Model-based driver for ``WorkQueue`` invariant testing.

A reference model (ordered pending list + inflight stamps + done set) is
stepped in lockstep with a real ``WorkQueue`` through an arbitrary
interleaving of claim / complete / expire / peek_ahead / clock-advance
operations.  After every step the queue must agree with the model AND its
internal indexes must be mutually consistent:

* ``_pending_set`` is authoritative: exactly the model's pending pids, each
  present in the global FIFO deque and (when device routing is bound) in
  its owner's deque — tombstones may linger in the deques but never in the
  set.
* ``peek_ahead`` is pure: it returns exactly the prefix fresh claims would
  take, and the queue's observable state is unchanged by the call.
* tombstones never resurrect: once ``complete(pid)`` wins, no later claim —
  fresh, fallback, or straggler re-issue — may return that pid.
* nothing is lost: drained to exhaustion, every partition completes as the
  winner exactly once.

Shared by ``test_properties.py`` (hypothesis draws the interleaving) and
``test_data.py`` (a seeded RNG draws it, so the invariants are exercised
even where hypothesis is not installed).
"""

from __future__ import annotations

from typing import Callable, List, Optional, Tuple

from repro.data.loader import WorkQueue

Op = Tuple  # ("advance", dt) | ("claim", reissue_only, prefer, fallback)
#              | ("complete", slot) | ("expire", slot) | ("peek", n, prefer)

TIMEOUT = 10.0


class ClockBox:
    """Manually-advanced virtual clock (the injectable ``clock`` callable)."""

    def __init__(self) -> None:
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _expected_fresh(
    pending: List[int],
    prefer: Optional[int],
    fallback: bool,
    owner_of: Optional[Callable[[int], int]],
) -> Optional[int]:
    """The pid a fresh claim must take: FIFO within each preference class."""
    if not pending:
        return None
    if prefer is None or owner_of is None:
        return pending[0]
    for p in pending:
        if owner_of(p) == prefer:
            return p
    # no local work: the scan takes the global FIFO head iff fallback admits
    return pending[0] if fallback else None


def _check_indexes(wq: WorkQueue, pending: List[int]) -> None:
    """White-box: membership set vs order-index deques (lazy tombstones)."""
    with wq._lock:
        assert wq._pending_set == set(pending)
        in_fifo = set(wq._pending)
        assert wq._pending_set <= in_fifo, "pending pid missing from FIFO index"
        if wq._by_dev is not None:
            assert wq.owner_of is not None
            by_dev = {p for dq in wq._by_dev.values() for p in dq}
            assert wq._pending_set <= by_dev, (
                "pending pid missing from its device's order index")
            for dev, dq in wq._by_dev.items():
                for p in dq:
                    if p in wq._pending_set:
                        assert wq.owner_of(p) == dev


def apply_ops(
    ops: List[Op],
    *,
    partitions: int = 12,
    devices: Optional[int] = 3,
    timeout: float = TIMEOUT,
    drain: bool = True,
) -> WorkQueue:
    """Run `ops` against a WorkQueue + reference model, asserting lockstep
    agreement after every operation; optionally drain to exhaustion and
    assert exactly-once winner delivery."""
    clock = ClockBox()
    owner_of = (lambda pid: pid % devices) if devices else None
    wq = WorkQueue(range(partitions), timeout, owner_of=owner_of, clock=clock)

    pending: List[int] = list(range(partitions))
    inflight: dict = {}  # pid -> model claim stamp
    done: set = set()
    winners: dict = {}  # pid -> winning completions observed

    def overdue_now() -> List[Tuple[float, int]]:
        return sorted(
            (t, p) for p, t in inflight.items()
            if clock.t - t > timeout and p not in done
        )

    def claimed_pool() -> List[int]:
        return sorted(set(inflight) | done)

    for op in ops:
        kind = op[0]
        if kind == "advance":
            clock.t += float(op[1])
        elif kind == "claim":
            _, reissue_only, prefer, fallback = op
            pid = wq.claim(
                reissue_only=bool(reissue_only),
                prefer_device=prefer,
                fallback_ok=(lambda p: True) if fallback else None,
            )
            exp = None if reissue_only else _expected_fresh(
                pending, prefer, fallback, owner_of)
            if exp is not None:
                assert pid == exp, f"fresh claim took {pid}, expected {exp}"
                assert pid not in done, "claim resurrected a completed pid"
                pending.remove(pid)
                inflight[pid] = clock.t
            else:
                od = overdue_now()
                if od:
                    assert pid == od[0][1], (
                        f"re-issue took {pid}, expected longest-overdue "
                        f"{od[0][1]}")
                    assert pid not in done
                    inflight[pid] = clock.t
                else:
                    assert pid is None, (
                        f"claim returned {pid} with nothing claimable")
        elif kind == "complete":
            pool = claimed_pool()
            if not pool:
                continue
            pid = pool[op[1] % len(pool)]
            won = wq.complete(pid)
            assert won == (pid not in done), "duplicate completion won"
            if won:
                winners[pid] = winners.get(pid, 0) + 1
            done.add(pid)
            inflight.pop(pid, None)
        elif kind == "expire":
            pool = claimed_pool() + pending
            if not pool:
                continue
            pid = pool[op[1] % len(pool)]
            hit = wq.expire(pid)
            assert hit == (pid in inflight and pid not in done)
            if hit:
                inflight[pid] = clock.t - timeout - 1.0
        elif kind == "peek":
            _, n, prefer = op
            before = wq.pending_snapshot()
            out = wq.peek_ahead(n, prefer_device=prefer)
            exp_order: List[int] = []
            if prefer is not None and owner_of is not None:
                exp_order += [p for p in pending if owner_of(p) == prefer]
            exp_order += [p for p in pending if p not in exp_order]
            assert out == exp_order[:max(n, 0)], "peek_ahead order diverged"
            assert wq.pending_snapshot() == before, "peek_ahead claimed"
        else:  # pragma: no cover - op generator bug
            raise AssertionError(f"unknown op {op!r}")

        # lockstep agreement after EVERY op
        assert wq.pending_snapshot() == pending
        assert wq.remaining() == len(pending) + len(inflight)
        _check_indexes(wq, pending)
        for probe in range(0, partitions, max(1, partitions // 4)):
            assert wq.is_pending(probe) == (probe in pending)

    if drain:
        # exactly-once delivery: drain whatever the interleaving left behind
        guard = 0
        while not wq.exhausted:
            pid = wq.claim()
            if pid is None:
                clock.t += timeout + 1.0  # make any straggler overdue
                pid = wq.claim()
            assert pid is not None, "queue not exhausted but nothing claimable"
            assert pid not in done, "drain resurrected a completed pid"
            if wq.complete(pid):
                winners[pid] = winners.get(pid, 0) + 1
            done.add(pid)
            inflight.pop(pid, None)
            if pid in pending:
                pending.remove(pid)
            guard += 1
            assert guard <= 10 * partitions + len(ops), "drain did not converge"
        assert sorted(winners) == list(range(partitions)), (
            "some partition never delivered")
        assert all(c == 1 for c in winners.values()), (
            "a partition delivered more than once")
    return wq


def random_ops(rng, n_ops: int, *, partitions: int, devices: int) -> List[Op]:
    """Seeded op-sequence generator (the no-hypothesis fallback driver)."""
    ops: List[Op] = []
    for _ in range(n_ops):
        r = rng.integers(0, 10)
        if r < 4:
            prefer = None if rng.integers(0, 2) else int(
                rng.integers(0, devices))
            ops.append(("claim", bool(rng.integers(0, 4) == 0), prefer,
                        bool(rng.integers(0, 2))))
        elif r < 6:
            ops.append(("complete", int(rng.integers(0, 64))))
        elif r < 7:
            ops.append(("expire", int(rng.integers(0, 64))))
        elif r < 8:
            prefer = None if rng.integers(0, 2) else int(
                rng.integers(0, devices))
            ops.append(("peek", int(rng.integers(0, partitions + 2)), prefer))
        else:
            ops.append(("advance", float(rng.uniform(0.0, TIMEOUT * 1.5))))
    return ops
