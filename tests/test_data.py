"""Data substrate: columnar files, synthetic sources, loader/straggler logic."""

import os
import tempfile
import time

import numpy as np
import pytest

from repro.data.columnar import decode_partition_numpy, read_partition, write_partition
from repro.data.loader import PrefetchLoader, WorkQueue
from repro.data.storage import PartitionedStore
from repro.data.synth import RM_CONFIGS, make_rm_source
from repro.data.tokens import TokenSynthesizer


def test_partition_roundtrip_all_rms():
    for name in ("rm1", "rm2"):
        src = make_rm_source(name, rows=128)
        part = src.partition(5)
        raw = src.raw(5)
        dec = decode_partition_numpy(part)
        np.testing.assert_allclose(dec["dense"]["d0"], raw.dense[:, 0])
        np.testing.assert_array_equal(
            dec["sparse_values"]["s0"], raw.sparse_values[:, 0]
        )
        np.testing.assert_array_equal(
            dec["sparse_lengths"]["s0"], raw.sparse_lengths[:, 0]
        )
        np.testing.assert_allclose(dec["dense"]["label"], raw.labels)


def test_partition_determinism():
    a = make_rm_source("rm1", rows=64).raw(7)
    b = make_rm_source("rm1", rows=64).raw(7)
    np.testing.assert_array_equal(a.sparse_values, b.sparse_values)
    np.testing.assert_allclose(a.dense, b.dense)


def test_disk_store_roundtrip():
    src = make_rm_source("rm1", rows=64)
    with tempfile.TemporaryDirectory() as d:
        store = PartitionedStore(8, num_devices=2, source=src, root=d)
        store.materialize(range(4))
        part = store.read(2)
        dec = decode_partition_numpy(part)
        raw = src.raw(2)
        np.testing.assert_array_equal(dec["sparse_values"]["s1"], raw.sparse_values[:, 1])
        # partitions land on the right simulated device dir
        assert store.owner_of(2) == 0 and store.owner_of(3) == 1
        assert os.path.exists(os.path.join(d, "device000", "part000002.rp"))


def test_work_queue_straggler_reissue():
    q = WorkQueue([0, 1], straggler_timeout=0.01)
    a = q.claim()
    b = q.claim()
    assert {a, b} == {0, 1}
    time.sleep(0.05)
    c = q.claim()  # re-issue of an overdue partition
    assert c in (0, 1) and q.reissues == 1
    assert q.complete(c) is True
    assert q.complete(c) is False  # duplicate completion dropped


def test_prefetch_loader_delivers_all():
    seen = []
    loader = PrefetchLoader(range(10), lambda pid: pid * 10, num_workers=3, depth=2)
    for pid, batch in loader:
        assert batch == pid * 10
        seen.append(pid)
    assert sorted(seen) == list(range(10))


def test_work_queue_fifo_claim_order():
    """Deque-backed pending preserves the original pop(0) FIFO semantics."""
    q = WorkQueue(range(5))
    assert [q.claim() for _ in range(5)] == list(range(5))
    assert q.claim() is None  # nothing overdue -> nothing to steal
    assert q.reissues == 0


def test_work_queue_reissue_only_skips_pending():
    q = WorkQueue([0, 1], straggler_timeout=0.0)
    assert q.claim(reissue_only=True) is None  # pending work is not fresh-claimable
    a = q.claim()
    time.sleep(0.01)
    assert q.claim(reissue_only=True) == a  # overdue straggler backup allowed
    assert q.reissues == 1


def test_prefetch_loader_stop_reaps_blocked_workers():
    """A worker blocked on a full output queue must honor stop(): the old
    blocking put() deadlocked shutdown when the consumer went away."""
    loader = PrefetchLoader(range(8), lambda pid: pid, num_workers=2, depth=1)
    loader.start()
    time.sleep(0.2)  # queue (depth 1) fills; workers block in the put loop
    t0 = time.time()
    loader.stop()  # joins: must return promptly with every thread dead
    assert time.time() - t0 < 2.0
    assert not any(t.is_alive() for t in loader._threads)


def test_work_queue_remaining_public():
    q = WorkQueue([0, 1, 2])
    assert q.total == 3 and q.remaining() == 3
    a = q.claim()
    assert q.remaining() == 3  # claimed-but-inflight still counts
    q.complete(a)
    assert q.remaining() == 2


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning"
)
def test_prefetch_loader_worker_death_raises():
    """A worker dying mid-produce must not hang the consumer forever."""

    def explode(pid):
        raise RuntimeError("storage device on fire")

    loader = PrefetchLoader(range(4), explode, num_workers=2, depth=2)
    with pytest.raises(RuntimeError, match="unfinished"):
        for _ in loader:
            pass


def test_token_synth_deterministic_sharding():
    synth = TokenSynthesizer(1000, 128, seed=1)
    a = synth.shard_batch(3, 7, 4)
    b = synth.shard_batch(3, 7, 4)
    c = synth.shard_batch(4, 7, 4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert (a["tokens"] != c["tokens"]).mean() > 0.5
    assert a["tokens"].max() < 1000 and a["tokens"].min() >= 1
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_work_queue_model_seeded_interleavings():
    """Model-based WorkQueue invariants under seeded random interleavings.

    The same driver `test_properties.py` feeds from hypothesis, driven here
    by a fixed-seed RNG so the invariants (_pending_set vs order-index
    deques, peek_ahead purity, tombstones never resurrecting a completed
    partition, exactly-once drain) are exercised even without hypothesis
    installed."""
    from workqueue_model import apply_ops, random_ops

    rng = np.random.default_rng(2024)
    for _ in range(60):
        parts = int(rng.integers(1, 20))
        devs = int(rng.integers(1, 5))
        ops = random_ops(rng, int(rng.integers(0, 60)),
                         partitions=parts, devices=devs)
        apply_ops(ops, partitions=parts, devices=devs)
