"""Operator-graph IR: structure, registry-driven lowering, placement parity,
cost-model placement, and per-placement-group provisioning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_recsys
from repro.core import opgraph
from repro.core.costmodel import choose_placement, placement_costs
from repro.core.opgraph import (
    FAMILIES,
    build_transform_graph,
    lower,
    lower_transform,
    resolve_placements,
    time_stages,
    group_times_by_placement,
)
from repro.core.planner import PlacementProvisioning
from repro.core.preprocess import pages_from_partition
from repro.core.presto import PreStoEngine
from repro.core.spec import TransformSpec
from repro.data.synth import SyntheticRecSysSource
from repro.kernels import FUSED_KERNELS


@pytest.fixture(scope="module")
def rm():
    """The recsys_rm config (reduced rm1) — the acceptance-criteria fixture."""
    rcfg = get_recsys("rm1", reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=256)
    spec = TransformSpec.from_source(src)
    pages = {k: jnp.asarray(v) for k, v in
             pages_from_partition(src.partition(0), spec).items()}
    return src, spec, pages


def test_graph_structure(rm):
    _, spec, _ = rm
    g = build_transform_graph(spec)
    assert g.families == FAMILIES
    # every family is a linear chain ending in the value form_batch consumes
    form = g.node("form_batch")
    for fam in FAMILIES:
        chain = g.family_chain(fam)
        assert chain, fam
        for a, b in zip(chain, chain[1:]):
            assert b.inputs == (a.output,), (fam, a.name, b.name)
        assert chain[-1].output in form.inputs
    # spec exposes the same graph
    assert [n.name for n in spec.graph().nodes] == [n.name for n in g.nodes]


def test_graph_rejects_bad_wiring(rm):
    _, spec, _ = rm
    g = build_transform_graph(spec)
    bad = tuple(
        n if n.name != "hash_sparse"
        else opgraph.SigridHash("hash_sparse", "sparse", ("nonexistent",),
                                "sparse_hashed", table="sparse")
        for n in g.nodes
    )
    with pytest.raises(ValueError, match="unknown values"):
        opgraph.OpGraph(nodes=bad, page_inputs=g.page_inputs)


def test_registry_drives_fusion(rm):
    _, spec, _ = rm
    plan = lower_transform(spec, "fused")
    fused = {st.family: st for st in plan.stages if st.kind.startswith("fused:")}
    # exactly the chains registered in FUSED_KERNELS fuse (dense/sparse/gen);
    # lengths/labels have no fused kernel and stay per-op even on ISP
    assert set(fused) == {"dense", "sparse", "gen"}
    g = build_transform_graph(spec)
    for fam, st in fused.items():
        kinds = tuple(n.kind for n in g.family_chain(fam))
        assert kinds in FUSED_KERNELS
        assert st.node_names == tuple(n.name for n in g.family_chain(fam))
    host_plan = lower_transform(spec, "unfused")
    assert not any(st.kind.startswith("fused:") for st in host_plan.stages)


def test_placement_parity_recsys_rm(rm):
    """Acceptance: presto/disagg/hybrid produce bitwise-identical batches."""
    _, spec, pages = rm
    plans = {
        "fused": lower_transform(spec, "fused"),
        "unfused": lower_transform(spec, "unfused"),
        "hybrid": lower_transform(spec, "hybrid"),
        "mixed": lower_transform(
            spec, {"dense": "host", "gen": "host", "labels": "host"}
        ),
    }
    outs = {name: p.execute(pages) for name, p in plans.items()}
    ref = outs["fused"]
    for name, mb in outs.items():
        for k in ref:
            np.testing.assert_array_equal(
                np.asarray(ref[k]), np.asarray(mb[k]), err_msg=f"{name}/{k}"
            )


def test_engine_placements_parity_recsys_rm(rm):
    """Acceptance: PreStoEngine(placement=hybrid) == presto == disagg."""
    _, spec, pages = rm
    outs = {
        pl: PreStoEngine(spec, mesh=None, placement=pl).jit_preprocess()(pages)
        for pl in ("presto", "disagg", "hybrid")
    }
    ref = outs["presto"]
    for pl, mb in outs.items():
        for k in ref:
            np.testing.assert_array_equal(
                np.asarray(ref[k]), np.asarray(mb[k]), err_msg=f"{pl}/{k}"
            )


def test_resolve_placements(rm):
    _, spec, _ = rm
    assert set(resolve_placements("fused", spec).values()) == {"isp"}
    assert set(resolve_placements("unfused", spec).values()) == {"host"}
    part = resolve_placements({"gen": "host"}, spec)
    assert part["gen"] == "host"
    assert all(part[f] == "isp" for f in FAMILIES if f != "gen")
    with pytest.raises(ValueError, match="unknown column families"):
        resolve_placements({"nope": "host"}, spec)
    with pytest.raises(ValueError, match="'isp' or 'host'"):
        resolve_placements({"gen": "gpu"}, spec)
    with pytest.raises(ValueError, match="unknown mode"):
        resolve_placements("warp", spec)


def test_cost_model_placement_shape(rm):
    """The chooser is deterministic, covers every family, and follows the
    bytes-vs-compute logic: the compute-heavy/byte-light gen chain leaves
    ISP before the byte-heavy dense/sparse chains do."""
    _, spec, _ = rm
    for s in (spec,):
        pl = choose_placement(s)
        assert set(pl) == set(FAMILIES)
        assert set(pl.values()) <= {"isp", "host"}
        assert pl == choose_placement(s)  # deterministic
    costs = placement_costs(spec)
    # gen's host-affinity (isp/host cost ratio) dominates dense's: bucketize's
    # binary search is pure compute while its bytes are tiny
    gen_ratio = costs["gen"]["isp"] / costs["gen"]["host"]
    dense_ratio = costs["dense"]["isp"] / costs["dense"]["host"]
    assert gen_ratio > dense_ratio


def test_stage_timing_groups(rm):
    _, spec, pages = rm
    plan = lower_transform(spec, {"gen": "host"})
    times = time_stages(plan, pages, iters=1, warmup=1)
    assert set(times) == {st.name for st in plan.stages}
    groups = group_times_by_placement(plan, times)
    assert set(groups) == {"isp", "host", "local"}
    assert all(t >= 0 for t in groups.values())


def test_placement_provisioning_math():
    plan = PlacementProvisioning.derive(1000.0, {"isp": 400.0, "host": 2500.0})
    assert plan.group_units == {"isp": 3, "host": 1}
    assert plan.total_units == 4
    assert plan.group_throughput["isp"] == 400.0
