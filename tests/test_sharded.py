"""Sharded behaviour (subprocesses with 8 fake devices): presto vs disagg
vs hybrid placement collectives, compressed train step, row-sharded embedding
bag, context-parallel decode attention."""

import pytest

from conftest import run_sharded


def test_presto_zero_collectives_disagg_permutes():
    out = run_sharded("""
import jax, numpy as np, jax.numpy as jnp
from repro.core.spec import TransformSpec
from repro.core.presto import PreStoEngine
from repro.core.preprocess import pages_from_partition
from repro.data.synth import RMDataConfig, SyntheticRecSysSource
from repro.launch.mesh import make_mesh
cfg = RMDataConfig("t", 4, 3, 4, 8, 2, 32, 1 << 16, 1024, rows_per_partition=256)
src = SyntheticRecSysSource(cfg, rows=256)
spec = TransformSpec.from_source(src)
mesh = make_mesh((4, 2), ("data", "model"))
pages = {k: jnp.asarray(v) for k, v in pages_from_partition(src.partition(0), spec).items()}
ep = PreStoEngine(spec, mesh, placement="presto")
ed = PreStoEngine(spec, mesh, placement="disagg")
mp = ep.jit_preprocess()(pages)
md = ed.jit_preprocess()(pages)
for k in mp:
    assert np.array_equal(np.asarray(mp[k]), np.asarray(md[k])), k
tp = jax.jit(ep.preprocess_global).lower(pages).compile().as_text()
td = jax.jit(ed.preprocess_global).lower(pages).compile().as_text()
from repro.launch.hlo_cost import analyze
cp, cd = analyze(tp), analyze(td)
assert cp.coll_bytes == 0, f"presto must move zero bytes, got {cp.coll_bytes}"
assert cd.coll_breakdown["collective-permute"] > 0, "disagg must permute"
print("PRESTO_COLL", cp.coll_bytes, "DISAGG_COLL", cd.coll_bytes)
""")
    assert "PRESTO_COLL 0" in out


def test_hybrid_collectives_only_for_host_families():
    """Hybrid placement must permute exactly the host-placed families'
    pages + outputs — nothing more (ISP families stay collective-free)."""
    out = run_sharded("""
import jax, numpy as np, jax.numpy as jnp
from repro.core import opgraph
from repro.core.spec import TransformSpec
from repro.core.presto import PreStoEngine
from repro.core.preprocess import pages_from_partition
from repro.data.synth import RMDataConfig, SyntheticRecSysSource
from repro.launch.hlo_cost import analyze
from repro.launch.mesh import make_mesh
cfg = RMDataConfig("t", 4, 3, 4, 8, 2, 32, 1 << 16, 1024, rows_per_partition=256)
src = SyntheticRecSysSource(cfg, rows=256)
spec = TransformSpec.from_source(src)
rows = 256
mesh = make_mesh((4, 2), ("data", "model"))
n_data = 4
pages = {k: jnp.asarray(v) for k, v in pages_from_partition(src.partition(0), spec).items()}
host_fams = ("gen", "lengths")
eh = PreStoEngine(spec, mesh,
                  placement={f: "host" for f in host_fams})
assert eh.placement == "hybrid" and eh.host_families() == host_fams
ep = PreStoEngine(spec, mesh, placement="presto")
mh = eh.jit_preprocess()(pages)
mp = ep.jit_preprocess()(pages)
for k in mh:
    assert np.array_equal(np.asarray(mh[k]), np.asarray(mp[k])), k
th = jax.jit(eh.preprocess_global).lower(pages).compile().as_text()
ch = analyze(th)
page_b = opgraph.family_page_bytes(spec, rows)
out_b = opgraph.family_batch_bytes(spec, rows)
expected = sum((page_b[f] + out_b[f]) // n_data for f in host_fams)
got = ch.coll_breakdown.get("collective-permute", 0)
assert got == expected, (got, expected)
assert ch.coll_bytes == got, "hybrid must emit no collectives beyond the host-family permutes"
# all-ISP "hybrid" degenerates to zero collectives
e0 = PreStoEngine(spec, mesh, placement={})
t0 = jax.jit(e0.preprocess_global).lower(pages).compile().as_text()
assert analyze(t0).coll_bytes == 0
print("HYBRID_PERMUTE_BYTES", got, "EXPECTED", expected)
""")
    assert "HYBRID_PERMUTE_BYTES" in out


def test_compressed_train_step_int8_collectives():
    out = run_sharded("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.models.config import ModelConfig
from repro.models import transformer as T
from repro.distributed.sharding import ShardingRules
from repro.train import adamw, warmup_cosine, make_train_step, make_compressed_train_step
from repro.train.compression import init_error_state
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rules_inner = ShardingRules.make(mesh, overrides={"batch": ("data",)})
cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32", remat="none")
opt = adamw(warmup_cosine(1e-3, 5, 50))
loss_inner = lambda p, b: T.loss_fn(p, b, cfg, rules_inner)
params = T.init_params(jax.random.PRNGKey(0), cfg)
state = {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32),
         "err": init_error_state(params)}
batch = {"tokens": jnp.ones((8, 64), jnp.int32), "labels": jnp.ones((8, 64), jnp.int32),
         "mask": jnp.ones((8, 64), jnp.float32)}
bspec = lambda b: {k: P("pod") if v.ndim == 1 else P("pod", None) for k, v in b.items()}
cstep = jax.jit(make_compressed_train_step(loss_inner, opt, mesh, bspec))
s1, m1 = cstep(state, batch)
s2, m2 = cstep(s1, batch)
assert float(m2["loss"]) < float(m1["loss"])
txt = cstep.lower(state, batch).compile().as_text()
# the cross-pod hop must carry int8: all-gather on current jax, the compat
# psum-slot emulation on old jax (either way the collective operand is s8)
n_s8 = sum(1 for l in txt.splitlines()
           if "s8" in l and ("all-gather" in l or "all-reduce" in l))
assert n_s8 > 0
# compressed step tracks an uncompressed step closely after one update
step = jax.jit(make_train_step(loss_inner, opt))
su, _ = step({k: state[k] for k in ("params", "opt", "step")}, batch)
import numpy as np
diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1["params"], su["params"])
md = max(jax.tree_util.tree_leaves(diffs))
assert md < 1e-3, md
print("INT8_AG", n_s8, "MAXDIFF", md)
""")
    assert "INT8_AG" in out


def test_rowsharded_embedding_matches_local():
    out = run_sharded("""
import jax, numpy as np, jax.numpy as jnp
from repro.configs.registry import get_recsys
from repro.distributed.sharding import ShardingRules
from repro.models import recsys as RS
from repro.launch.mesh import make_mesh
rcfg = get_recsys("rm1", reduced=True)
mesh = make_mesh((2, 4), ("data", "model"))
rules_m = ShardingRules.make(mesh)
rules_l = ShardingRules.make(None)
params = RS.init_params(jax.random.PRNGKey(0), rcfg)
rng = np.random.default_rng(0)
B, S, L, G = 16, rcfg.data.n_sparse, rcfg.data.max_sparse_len, rcfg.data.n_generated
mids = jnp.asarray(rng.integers(0, rcfg.data.embedding_rows, (B, S, L)), jnp.int32)
lens = jnp.asarray(rng.integers(1, L + 1, (B, S)), jnp.int32)
oids = jnp.asarray(rng.integers(0, rcfg.data.embedding_rows, (B, G)), jnp.int32)
local = RS.embedding_bag(params["tables"], mids, lens, oids, rcfg, rules_l)
sharded = jax.jit(lambda t: RS.embedding_bag(t, mids, lens, oids, rcfg, rules_m))(params["tables"])
np.testing.assert_allclose(np.asarray(local), np.asarray(sharded), rtol=2e-5, atol=2e-5)
print("EMB_OK")
""")
    assert "EMB_OK" in out


def test_cp_decode_attention_matches_plain():
    out = run_sharded("""
import jax, numpy as np, jax.numpy as jnp
from repro.models.layers import decode_attention, cp_decode_attention
from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(0)
B, S, K, G, D = 1, 256, 2, 4, 16
q = jnp.asarray(rng.normal(size=(B, 1, K * G, D)), jnp.float32)
kc = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
vc = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
clen = jnp.full((B,), 100, jnp.int32)
plain = decode_attention(q, kc, vc, clen)
cp = jax.jit(lambda q, k, v, n: cp_decode_attention(q, k, v, n, mesh=mesh, axis="data"))(q, kc, vc, clen)
np.testing.assert_allclose(np.asarray(plain), np.asarray(cp), rtol=1e-5, atol=1e-5)
print("CP_OK")
""")
    assert "CP_OK" in out
