"""End-to-end behaviour: the paper's full pipeline (Fig. 1 / Fig. 9) —
Extract (store) -> Transform (PreSto engine) -> Load -> DLRM training —
plus the T/P provisioning planner and the fused ingest+train program."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_recsys
from repro.core.pipeline import TrainingPipeline
from repro.core.planner import ProvisioningPlan, paper_speedup_per_unit
from repro.core.presto import PreStoEngine
from repro.core.spec import TransformSpec
from repro.data.storage import PartitionedStore
from repro.data.synth import SyntheticRecSysSource
from repro.distributed.sharding import ShardingRules
from repro.models import recsys as RS
from repro.train import adamw, make_train_step, make_train_step_with_ingest, warmup_cosine

RULES = ShardingRules.make(None)


@pytest.fixture(scope="module")
def setup():
    rcfg = get_recsys("rm1", reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=256)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(16, num_devices=4, source=src)
    engine = PreStoEngine(spec, mesh=None)
    params = RS.init_params(jax.random.PRNGKey(0), rcfg)
    opt = adamw(warmup_cosine(1e-3, 5, 200))
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}
    loss_fn = lambda p, b: RS.loss_fn(p, b, rcfg, RULES)
    return rcfg, src, spec, store, engine, state, opt, loss_fn


def test_pipeline_trains_and_tracks_utilization(setup):
    rcfg, src, spec, store, engine, state, opt, loss_fn = setup
    step = jax.jit(make_train_step(loss_fn, opt))
    pipe = TrainingPipeline(engine, store, step, num_workers=2)
    state, stats, metrics = pipe.run(state, range(16), max_steps=12)
    assert stats.steps == 12
    assert 0.0 < stats.utilization <= 1.0
    assert np.isfinite(metrics[-1]["loss"])


def test_provisioning_plan(setup):
    rcfg, src, spec, store, engine, state, opt, loss_fn = setup
    step = jax.jit(make_train_step(loss_fn, opt))
    pipe = TrainingPipeline(engine, store, step)
    plan = pipe.provision(state)
    assert plan.workers_required >= 1
    assert plan.workers_required == -(-plan.train_throughput // plan.worker_throughput)
    # paper-anchored per-unit speedups: ISP unit ~ 40x a CPU core
    assert 35 < paper_speedup_per_unit("rm3") < 45


def test_fused_ingest_train_program(setup):
    """One jit program: encoded pages in, updated params out."""
    rcfg, src, spec, store, engine, state, opt, loss_fn = setup
    fused = jax.jit(make_train_step_with_ingest(engine, loss_fn, opt))
    pages = {k: jnp.asarray(v) for k, v in engine.stage_partition(store, 0).items()}
    s1, m1 = fused(state, pages)
    s2, m2 = fused(s1, pages)
    assert float(m2["loss"]) < float(m1["loss"])
    # equivalence with the two-program path
    mb = engine.jit_preprocess()(pages)
    step = jax.jit(make_train_step(loss_fn, opt))
    s1b, m1b = step(state, mb)
    assert abs(float(m1["loss"]) - float(m1b["loss"])) < 1e-5


def test_straggler_reissue_preserves_results(setup):
    """Duplicate partition production (straggler backup) must not corrupt
    training: partitions are deterministic, winner-takes-first."""
    rcfg, src, spec, store, engine, state, opt, loss_fn = setup
    step = jax.jit(make_train_step(loss_fn, opt))
    pipe = TrainingPipeline(engine, store, step, num_workers=3,
                            straggler_timeout=0.0)  # aggressive re-issue
    state, stats, metrics = pipe.run(state, range(8), max_steps=8)
    assert stats.steps == 8
    assert np.isfinite(metrics[-1]["loss"])
