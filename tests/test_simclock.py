"""Discrete-event sim engine: determinism, virtual-time ledgers, SLO vs FIFO.

FoundationDB-style deterministic-simulation tests: every scenario is a pure
function of (seed, schedule), so replaying it must reproduce the *byte
identical* event trace — including chaos (worker kill/join at modeled
instants).  On top, the SLO admission semantics the sim exists to measure:
overloaded schedules reject/degrade up front instead of starving the tail,
release candidates preempt exploratory tenants, and the whole thousand-
session regime runs in wall-clock seconds because nothing ever sleeps.
"""

import time

import pytest

from repro.core.costmodel import ContentionAwareCostModel
from repro.core.simclock import (
    SimEngine,
    SimJob,
    VirtualClock,
    synthetic_costs,
    zipf_sessions,
)
from repro.data.storage import DeviceFleet


# -- the event core ------------------------------------------------------------


def test_virtual_clock_never_rewinds():
    clk = VirtualClock()
    clk.advance_to(2.5)
    assert clk.now() == 2.5
    with pytest.raises(ValueError, match="rewind"):
        clk.advance_to(1.0)


def test_engine_orders_by_time_then_schedule_order():
    """(time, seq) heap order: same-instant events fire in schedule order,
    and scheduling into the past is an error."""
    eng = SimEngine()
    fired = []
    eng.at(2.0, lambda: fired.append("late"))
    eng.at(1.0, lambda: fired.append("a"))
    eng.at(1.0, lambda: fired.append("b"))
    eng.at(1.0, lambda: fired.append("c"))
    eng.at(0.5, lambda: fired.append("early"))
    n = eng.run()
    assert fired == ["early", "a", "b", "c", "late"]
    assert n == 5 and eng.now == 2.0
    with pytest.raises(ValueError, match="past"):
        eng.at(1.0, lambda: None)


def test_engine_events_may_schedule_more_events():
    eng = SimEngine()
    fired = []

    def tick(i):
        fired.append((eng.now, i))
        if i < 3:
            eng.after(1.0, lambda: tick(i + 1))

    eng.at(0.0, lambda: tick(0))
    eng.run()
    assert fired == [(0.0, 0), (1.0, 1), (2.0, 2), (3.0, 3)]


# -- virtual-time device occupancy ---------------------------------------------


def test_isp_device_reserve_serializes_in_time():
    """reserve() models the device as busy IN TIME: back-to-back reserves
    queue behind free_at, and the same ledgers the wall-clock path charges
    accumulate identically."""
    fleet = DeviceFleet.from_cost_model(1, ContentionAwareCostModel())
    dev = fleet[0]
    s0, e0 = dev.reserve(0.0, 1.0, nbytes=100, ops=5.0)
    s1, e1 = dev.reserve(0.0, 1.0, nbytes=100, ops=5.0)
    assert (s0, e0) == (0.0, 1.0)
    assert (s1, e1) == (1.0, 2.0)  # queued behind the first
    s2, e2 = dev.reserve(5.0, 0.5)
    assert (s2, e2) == (5.0, 5.5)  # idle gap: starts at now, not free_at
    assert dev.busy_s == pytest.approx(2.5)
    assert dev.bytes_streamed == 200
    assert dev.compute_ops == pytest.approx(10.0)


def test_fleet_reserve_host_parallel_slots():
    """Host-side reserves fill `parallelism` slots before queueing."""
    fleet = DeviceFleet.from_cost_model(2, ContentionAwareCostModel())
    a = fleet.reserve_host(0.0, 1.0, parallelism=2)
    b = fleet.reserve_host(0.0, 1.0, parallelism=2)
    c = fleet.reserve_host(0.0, 1.0, parallelism=2)
    assert a == (0.0, 1.0) and b == (0.0, 1.0)  # two slots run concurrently
    assert c == (1.0, 2.0)  # third waits for the earliest-free slot
    assert fleet.host_busy_s == pytest.approx(3.0)
    assert fleet.host_produces == 3


# -- deterministic replay ------------------------------------------------------


def _chaos_scenario(sim_harness, seed):
    h = sim_harness(seed=seed, num_workers=4, num_devices=2,
                    straggler_timeout=0.05)
    h.workload(40, arrival_window_s=0.5)
    h.kill_at(0.02, 1)
    h.kill_at(0.30, 0)
    h.join_at(0.40)
    return h


def test_same_seed_replay_is_byte_identical(sim_harness):
    runs = []
    for _ in range(2):
        h = _chaos_scenario(sim_harness, seed=11)
        h.run()
        runs.append(h.trace_bytes())
    assert runs[0] == runs[1]
    assert len(runs[0]) > 1000  # a real trace, not an empty log

    other = _chaos_scenario(sim_harness, seed=12)
    other.run()
    assert other.trace_bytes() != runs[0]  # the seed is load-bearing


def test_kill_mid_flight_reissues_and_still_delivers(sim_harness):
    """A worker killed while holding a claim: its completion goes stale,
    the claim is force-expired onto the straggler path, and the job still
    delivers every partition — deterministically on replay."""
    def scenario():
        h = sim_harness(seed=5, num_workers=2, num_devices=2,
                        straggler_timeout=0.05)
        h.submit(SimJob("victim", partitions=8))
        # kill wid=0 inside the first produce (isp_s ~ 10ms per partition)
        h.kill_at(0.004, 0)
        return h

    h = scenario()
    rep = h.run()
    (out,) = rep.outcomes
    # the kill halves capacity, so the replan may degrade the survivor —
    # but it must still finish
    assert out.status in ("admitted", "degraded") and out.finish_s is not None
    assert out.partitions == 8
    events = h.service.events.since(0)
    kinds = [e.kind for e in events]
    assert "kill" in kinds and "claim_expired" in kinds
    assert "claim_reissue" in kinds  # the straggler path re-issued it
    completes = [e for e in events if e.kind == "complete"]
    assert sorted({e.data["pid"] for e in completes}) == list(range(8))

    h2 = scenario()
    h2.run()
    assert h2.trace_bytes() == h.trace_bytes()


# -- SLO semantics -------------------------------------------------------------


def test_slo_rejects_and_degrades_instead_of_starving(sim_harness):
    """Overloaded schedule: SLO admission sheds load at arrival (rejected /
    degraded outcomes), nothing admitted starves; the FIFO baseline admits
    everything and starves the tail of the SAME workload."""
    reports = {}
    for policy in ("slo", "fifo"):
        h = sim_harness(seed=3, policy=policy, num_workers=4, num_devices=2)
        h.workload(300, arrival_window_s=1.2)
        reports[policy] = h.run()

    slo, fifo = reports["slo"], reports["fifo"]
    assert slo.starved_count == 0
    shed = [o for o in slo.outcomes if o.status in ("rejected", "degraded")]
    assert shed, "an overloaded SLO schedule must visibly shed load"
    assert all(o.slo_met is None for o in slo.outcomes if o.status == "rejected")
    assert fifo.starved_count > 0
    by_cls = fifo.by_class()
    assert all(row["rejected"] == 0 for row in by_cls.values())  # FIFO admits all
    # and the sim is why this test can exist: 600 sessions of modeled
    # schedule cost heap pops, not threads


def test_rc_preempts_exploratory(sim_harness):
    """A release candidate arriving into a full pool preempts the
    exploratory tenant (its share drops to the backfill pass), and both
    still finish — preemption degrades, it does not kill."""
    h = sim_harness(seed=0, policy="slo", num_workers=1, num_devices=1)
    h.submit(SimJob("explore", partitions=6, arrival_s=0.0, demand_units=1))
    h.submit(SimJob("rc", partitions=4, arrival_s=0.005, demand_units=1,
                    qos_class="rc"))
    rep = h.run()
    pre = h.service.events.tail(1000, kind="preempt")
    assert pre and pre[0].data["job"] == "explore"
    assert pre[0].data["by"] == "rc"
    out = {o.name: o for o in rep.outcomes}
    assert out["rc"].finish_s is not None
    assert out["explore"].finish_s is not None
    assert out["rc"].finish_s < out["explore"].finish_s


def test_zipf_workload_shape():
    eng = SimEngine(seed=7)
    jobs = zipf_sessions(500, rng=eng.rng, arrival_window_s=10.0)
    assert len(jobs) == 500
    sizes = sorted(j.partitions for j in jobs)
    # heavy-tailed: a long tail of tiny sessions, huge ones clipped at the cap
    assert sizes[len(sizes) // 2] <= 8 < sizes[-1] == 64
    arrivals = [j.arrival_s for j in jobs]
    assert arrivals == sorted(arrivals)
    assert all(0.0 <= a <= 10.0 for a in arrivals)
    rc = [j for j in jobs if j.qos_class == "rc"]
    assert 0 < len(rc) < len(jobs) // 2
    assert all(j.deadline_s and j.deadline_s > 0 for j in jobs)


def test_thousand_sessions_in_wall_clock_seconds(sim_harness):
    """The acceptance bar: a 1000-session schedule must be wall-clock
    seconds, and every session must be accounted for (finished or
    rejected — nothing lost, nothing stuck)."""
    h = sim_harness(seed=3, policy="slo", num_workers=8, num_devices=4)
    h.workload(1000, arrival_window_s=4.0)
    t0 = time.perf_counter()
    rep = h.run()
    wall = time.perf_counter() - t0
    assert wall < 30.0, f"virtual-time run took {wall:.1f}s of real time"
    assert len(rep.outcomes) == 1000
    for o in rep.outcomes:
        assert (o.status == "rejected") == (o.finish_s is None)
    assert rep.makespan_s > 0 and rep.events_processed > 1000


def test_synthetic_costs_prefer_isp():
    model = ContentionAwareCostModel()
    costs = synthetic_costs(model)
    assert 0 < costs.isp_s < costs.host_s  # the byte-bound regime
