# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device.  Sharded behaviour is tested via subprocesses that
# set --xla_force_host_platform_device_count themselves.
import os
import subprocess
import sys

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_sharded(script: str, devices: int = 8, timeout: int = 420) -> str:
    """Run a python snippet in a subprocess with N fake devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    assert proc.returncode == 0, f"sharded subprocess failed:\n{proc.stderr[-4000:]}"
    return proc.stdout


@pytest.fixture
def sim_harness():
    """Factory for seeded virtual-time scenario harnesses (core.simclock).

    Usage: ``h = sim_harness(seed=7, policy="slo", num_workers=4)`` —
    everything the harness runs happens in virtual time (no real sleeps),
    and a same-seed, same-schedule harness must replay a byte-identical
    event trace (``h.trace_bytes()``)."""
    from repro.core.simclock import SimHarness

    def make(seed: int = 0, **service_kwargs):
        return SimHarness(seed=seed, **service_kwargs)

    return make
