"""Content-addressed feature cache: keys, tiers, cross-tenant dedup, planner.

The load-bearing invariant: a cache hit is bitwise identical to the cold
compute it replaces — preprocessing is deterministic in (partition bytes,
lowered Transform, placement), and those three ARE the key.
"""

import os
import threading
import time

import numpy as np
import pytest

from repro.configs.registry import get_recsys
from repro.core.featcache import CacheKey, FeatureCache, batch_nbytes
from repro.core.planner import effective_demand_units, plan_pool
from repro.core.presto import PreStoEngine
from repro.core.service import JobSpec, PreprocessingService
from repro.core.spec import TransformSpec
from repro.data.storage import CacheSpillStore, PartitionedStore
from repro.data.synth import SyntheticRecSysSource


@pytest.fixture(scope="module")
def rm1():
    rcfg = get_recsys("rm1", reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=128)
    spec = TransformSpec.from_source(src)
    store = PartitionedStore(12, num_devices=4, source=src)
    engine = PreStoEngine(spec)
    return rcfg, src, spec, store, engine


def _batch(pid: int, kb: int = 8):
    rng = np.random.default_rng(pid)
    return {
        "labels": rng.random(kb * 256).astype(np.float32),  # kb KiB
        "dense": np.full((4,), pid, np.int32),
    }


def _key(i: int, plan: str = "plan", placement: str = "presto") -> CacheKey:
    return CacheKey(f"part{i:04d}", plan, placement)


# -- content addressing -------------------------------------------------------


def test_structural_hash_survives_relowering(rm1):
    rcfg, src, spec, store, engine = rm1
    h1 = engine.lowered_plan.structural_hash()
    # an INDEPENDENT lowering of an INDEPENDENT spec over equal content
    spec2 = TransformSpec.from_source(SyntheticRecSysSource(rcfg.data, rows=128))
    h2 = PreStoEngine(spec2).lowered_plan.structural_hash()
    assert h1 == h2
    # kernel placement is part of the plan structure...
    h_host = PreStoEngine(spec2, kernel_mode="unfused").lowered_plan.structural_hash()
    assert h_host != h1
    # ...and comm placement is part of the engine signature (disagg lowers
    # the same fused kernels, so only the signature separates it)
    assert PreStoEngine(spec2).cache_signature() == engine.cache_signature()
    sig_disagg = PreStoEngine(spec2, placement="disagg").cache_signature()
    assert sig_disagg != engine.cache_signature()


def test_partition_fingerprint_content_addressed(rm1):
    rcfg, src, spec, store, engine = rm1
    # a different store OBJECT over equal content fingerprints identically
    store2 = PartitionedStore(
        12, num_devices=2, source=SyntheticRecSysSource(rcfg.data, rows=128)
    )
    assert store.partition_fingerprint(3) == store2.partition_fingerprint(3)
    assert store.partition_fingerprint(3) != store.partition_fingerprint(4)
    # different content (rows) => different fingerprint
    store3 = PartitionedStore(
        12, num_devices=4, source=SyntheticRecSysSource(rcfg.data, rows=64)
    )
    assert store.partition_fingerprint(3) != store3.partition_fingerprint(3)


def test_disk_backed_fingerprint_hashes_file_bytes(rm1, tmp_path):
    rcfg, src, spec, store, engine = rm1
    d1, d2 = tmp_path / "a", tmp_path / "b"
    s1 = PartitionedStore(4, num_devices=2, source=src, root=str(d1))
    s2 = PartitionedStore(4, num_devices=2, source=src, root=str(d2))
    # no file yet: the source's deterministic identity is the content
    assert s1.partition_fingerprint(2) == store.partition_fingerprint(2)
    s1.materialize([0, 1])
    s2.materialize([0, 1])
    # once a file exists it is what read() serves, so it wins the
    # fingerprint; identical materialized bytes agree across stores —
    # sourced or not
    fp0 = s1.partition_fingerprint(0)
    assert fp0 == s2.partition_fingerprint(0)
    assert fp0 != s1.partition_fingerprint(1)
    p1 = PartitionedStore(4, num_devices=2, root=str(d1))
    assert p1.partition_fingerprint(0) == fp0
    # rewritten file bytes => new fingerprint (stat revalidation), so a
    # foreign file can cost a missed dedup but never a wrong batch
    path = p1._path(0)
    with open(path, "ab") as f:
        f.write(b"\0" * 8)
    os.utime(path, ns=(1, 1))  # force a distinct stat signature
    assert p1.partition_fingerprint(0) != fp0
    assert s1.partition_fingerprint(0) != fp0  # sourced store revalidates too


# -- the cache proper ---------------------------------------------------------


def test_hit_is_bitwise_identical_to_cold_compute(rm1):
    rcfg, src, spec, store, engine = rm1
    cold = engine.produce_batch(store, 0)
    cache = FeatureCache(64 << 20)
    key = CacheKey(store.partition_fingerprint(0), engine.cache_signature(),
                   engine.placement)
    assert cache.get(key) is None
    cache.put(key, cold)
    hit = cache.get(key)
    assert hit is not None
    for k in cold:
        np.testing.assert_array_equal(np.asarray(cold[k]), np.asarray(hit[k]))
    st = cache.stats()
    assert st.hits == 1 and st.misses == 1 and st.insertions == 1


def test_lru_eviction_under_memory_bound():
    one = batch_nbytes(_batch(0))
    cache = FeatureCache(capacity_bytes=3 * one)
    for i in range(5):
        cache.put(_key(i), _batch(i))  # three fit exactly
    st = cache.stats()
    assert st.evictions == 2 and st.entries == 3
    assert st.resident_bytes <= cache.capacity_bytes
    # the two oldest were evicted, the three newest survive
    assert cache.get(_key(0)) is None and cache.get(_key(1)) is None
    assert all(cache.get(_key(i)) is not None for i in (2, 3, 4))
    # recency: touching 2 makes 3 the LRU victim of the next insert
    cache.get(_key(2))
    cache.put(_key(5), _batch(5))
    assert cache.get(_key(3)) is None and cache.get(_key(2)) is not None


def test_eviction_spills_and_spill_hit_promotes():
    spill = CacheSpillStore(num_devices=3, bytes_per_s=1e6)
    cache = FeatureCache(capacity_bytes=2 * batch_nbytes(_batch(0)), spill=spill)
    for i in range(4):
        cache.put(_key(i), _batch(i))
    assert cache.stats().evictions == 2
    assert len(spill) == 2 and spill.bytes_written > 0
    # evicted key 0 is served by the spill tier, bitwise intact, and charged
    # to the byte-movement model
    io0 = spill.modeled_io_s
    block = cache.get(_key(0))
    assert block is not None
    np.testing.assert_array_equal(block["labels"], _batch(0)["labels"])
    st = cache.stats()
    assert st.spill_hits == 1 and spill.bytes_read > 0
    assert spill.modeled_io_s > io0
    # promotion put it back in the memory tier (next get is a memory hit)
    hits0 = st.hits
    assert cache.get(_key(0)) is not None
    assert cache.stats().spill_hits == 1 and cache.stats().hits == hits0 + 1


def test_spill_store_disk_roundtrip(tmp_path):
    spill = CacheSpillStore(num_devices=2, root=str(tmp_path))
    arrays = {"a": np.arange(7, dtype=np.float32), "b": np.eye(3, dtype=np.int32)}
    n = spill.write("blk", arrays)
    assert n == arrays["a"].nbytes + arrays["b"].nbytes
    back = spill.read("blk")
    np.testing.assert_array_equal(back["a"], arrays["a"])
    np.testing.assert_array_equal(back["b"], arrays["b"])
    assert spill.read("missing") is None


def test_inflight_begin_follow_fulfill():
    cache = FeatureCache(1 << 20)
    status, val = cache.begin(_key(0))
    assert status == "produce" and val is None
    status, fut = cache.begin(_key(0))
    assert status == "follow" and not fut.done()
    cache.fulfill(_key(0), _batch(0))
    np.testing.assert_array_equal(
        fut.result(timeout=1)["labels"], _batch(0)["labels"])
    assert cache.begin(_key(0))[0] == "hit"
    # abandon with an error propagates to followers
    assert cache.begin(_key(1))[0] == "produce"
    _, fut2 = cache.begin(_key(1))
    cache.abandon(_key(1), RuntimeError("device on fire"))
    with pytest.raises(RuntimeError, match="on fire"):
        fut2.result(timeout=1)


# -- service integration: cross-tenant dedup ----------------------------------


def test_two_overlapping_sessions_dedup_hits(rm1):
    rcfg, src, spec, store, engine = rm1

    cache = FeatureCache(256 << 20)
    with PreprocessingService(num_workers=2, cache=cache) as svc:
        a = svc.submit(JobSpec(name="a", partitions=range(0, 8), engine=engine,
                               store=store, units=2))
        out_a = {pid: mb for pid, mb in a}
        b = svc.submit(JobSpec(name="b", partitions=range(4, 12), engine=engine,
                               store=store, units=2))
        out_b = {pid: mb for pid, mb in b}

    sa, sb = a.stats(), b.stats()
    assert sa.cache_hits == 0 and sa.cache_misses == 8
    assert sb.cache_hits == 4 and sb.cache_misses == 4  # pids 4..7 shared
    assert sorted(out_b) == list(range(4, 12))
    for pid in range(4, 8):  # shared pids: byte-for-byte the same batch
        for k in out_a[pid]:
            np.testing.assert_array_equal(
                np.asarray(out_a[pid][k]), np.asarray(out_b[pid][k]),
                err_msg=f"pid={pid} key={k} diverged through the cache")
    cs = cache.stats()
    assert cs.hits + cs.follows >= 4
    assert svc.stats()["cache"].insertions >= 8


def test_concurrent_overlapping_sessions_share_inflight(rm1):
    """Tenants racing the same cold partitions: every shared pid is produced
    once — the second tenant hits or follows, never recomputes."""
    rcfg, src, spec, store, engine = rm1
    cache = FeatureCache(256 << 20)
    outs = {"a": {}, "b": {}}
    with PreprocessingService(num_workers=4, cache=cache) as svc:
        # produce_fn would be uncacheable (opaque); emulate a cacheable slow
        # produce by submitting engine jobs against a slow store wrapper
        class SlowStore(PartitionedStore):
            def read(self, pid):
                time.sleep(0.02)
                return super().read(pid)

        slow = SlowStore(12, num_devices=4, source=src)
        sessions = {
            name: svc.submit(JobSpec(name=name, partitions=range(0, 6),
                                     engine=engine, store=slow, units=2))
            for name in outs
        }
        threads = [
            threading.Thread(
                target=lambda n: outs[n].update({p: m for p, m in sessions[n]}),
                args=(name,))
            for name in outs
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    assert sorted(outs["a"]) == sorted(outs["b"]) == list(range(6))
    cs = cache.stats()
    # 12 probes over 6 distinct partitions: ≥6 served without a produce
    assert cs.misses == 6
    assert cs.hits + cs.follows == 6
    for pid in range(6):
        for k in outs["a"][pid]:
            np.testing.assert_array_equal(
                np.asarray(outs["a"][pid][k]), np.asarray(outs["b"][pid][k]))


def test_produce_fn_jobs_bypass_cache():
    cache = FeatureCache(1 << 20)
    with PreprocessingService(num_workers=2, cache=cache) as svc:
        s = svc.submit(JobSpec(name="opaque", partitions=range(4),
                               produce_fn=lambda pid: {"pid": pid}))
        assert sorted(pid for pid, _ in s) == list(range(4))
    assert cache.stats().probes == 0
    assert s.stats().cache_hits == 0 and s.stats().cache_misses == 0


# -- restart survival: rescan + warm start ------------------------------------


def test_spill_store_rescans_blocks_after_restart(tmp_path):
    spill = CacheSpillStore(num_devices=2, root=str(tmp_path))
    arrays = {"a": np.arange(9, dtype=np.float32)}
    spill.write("blk1", arrays)
    spill.write("blk2", {"a": np.ones(4, np.int32)})
    # a new store object over the same root (the restart) rebuilds residency
    reborn = CacheSpillStore(num_devices=2, root=str(tmp_path))
    assert set(reborn.keys()) == {"blk1", "blk2"}
    assert "blk1" in reborn and reborn.resident_bytes > 0
    io0 = reborn.io_s_by_device[reborn.owner_of("blk1")]
    back = reborn.read("blk1")
    np.testing.assert_array_equal(back["a"], arrays["a"])
    # the restored read charges its owning device's ledger
    assert reborn.io_s_by_device[reborn.owner_of("blk1")] > io0


def test_warm_start_restarted_service_serves_bitwise_hits(rm1, tmp_path):
    """Satellite: a restarted service rebuilds the cache from the spill
    tier's .npz blocks and serves bitwise-identical hits without a single
    recompute."""
    rcfg, src, spec, store, engine = rm1
    cold = engine.produce_batch(store, 0)
    capacity = int(1.5 * sum(int(np.asarray(v).nbytes) for v in cold.values()))

    def boot():
        spill = CacheSpillStore(num_devices=2, root=str(tmp_path))
        cache = FeatureCache(capacity_bytes=capacity, spill=spill)
        return cache, PreprocessingService(num_workers=2, cache=cache)

    def job():
        return JobSpec(name="warm", partitions=range(6), engine=engine,
                       store=store, units=2)

    cache1, svc1 = boot()
    with svc1:
        out1 = {pid: mb for pid, mb in svc1.submit(job())}
    # close() flushed the memory tier: every produced batch survives on disk
    assert len(cache1.spill) >= 6

    cache2, svc2 = boot()  # the restart: boot warm-starts from the blocks
    with svc2:
        assert cache2.stats().warm_started >= 1
        sess = svc2.submit(job())
        out2 = {pid: mb for pid, mb in sess}
        st = sess.stats()
    assert st.cache_hits == 6 and st.cache_misses == 0
    assert st.produced == 0  # not one recompute after the restart
    for pid in out1:
        for k in out1[pid]:
            np.testing.assert_array_equal(
                np.asarray(out1[pid][k]), np.asarray(out2[pid][k]),
                err_msg=f"pid={pid} key={k} diverged across the restart")


# -- planner: hit-rate demand discount ----------------------------------------


def test_effective_demand_units_discount():
    assert effective_demand_units(8, 0.0) == 8
    assert effective_demand_units(8, 0.5) == 4
    assert effective_demand_units(8, 1.0) == 1  # QoS floor
    assert effective_demand_units(3, 0.5) == 2  # ceil
    assert effective_demand_units(4, 2.0) == 1  # clamped rate


def test_plan_pool_discounts_hot_jobs_toward_cold_ones():
    # without hit rates: equal demand, equal split
    plan = plan_pool(8, {"hot": 6, "cold": 6})
    assert plan.shares == {"hot": 4, "cold": 4}
    # the hot job's 2/3 hit rate frees units that flow to the cold job
    plan = plan_pool(8, {"hot": 6, "cold": 6}, {"hot": 2 / 3, "cold": 0.0})
    assert plan.effective_demand == {"hot": 2, "cold": 6}
    assert plan.shares == {"hot": 2, "cold": 6}
    assert not plan.oversubscribed  # effective 8 fits the pool
    assert plan.demand_units == {"hot": 6, "cold": 6}  # raw demand recorded


def test_service_rebalances_on_hit_rate_change(rm1):
    """A session whose claims start hitting sheds share to the cold tenant."""
    rcfg, src, spec, store, engine = rm1
    cache = FeatureCache(256 << 20)
    # warm the cache with the hot tenant's whole range
    with PreprocessingService(num_workers=2, cache=cache) as svc:
        svc.submit(JobSpec(name="warm", partitions=range(0, 6), engine=engine,
                           store=store, units=2)).drain()

    def slow_produce(pid):
        time.sleep(0.01)
        return {"pid": pid}

    with PreprocessingService(num_workers=4, cache=cache) as svc:
        cold = svc.submit(JobSpec(name="cold", partitions=range(200),
                                  produce_fn=slow_produce, units=4))
        it = iter(cold)
        next(it)
        hot = svc.submit(JobSpec(name="hot", partitions=range(0, 6),
                                 engine=engine, store=store, units=3))
        # on join, before any probe: raw demands 4 + 3 over 4 units
        out_hot = {pid: mb for pid, mb in hot}
        # hot's 100% hit rate discounts its demand to the 1-unit floor; the
        # next re-plan hands the freed units to the cold job (3 while hot is
        # still admitted, 4 once it retires)
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            if hot.stats().done and svc.plan.shares.get("cold", 0) >= 3:
                break
            next(it, None)
            time.sleep(0.005)
        st = hot.stats()
        plan = svc.plan
        cold.cancel()
    assert sorted(out_hot) == list(range(6))
    assert st.cache_hits == 6 and st.cache_misses == 0  # fully cache-fed
    assert st.effective_demand_units == 1  # discounted to the floor
    assert plan.shares.get("cold", 0) >= 3


# -- predictive pre-warm (peek-window probes) ---------------------------------


def test_prewarm_probe_counts_apart_from_claim_path():
    """Pre-warm probes get identical tier effects but are tallied under
    prewarm_hits/prewarm_leases, never hits/follows/misses — hit_rate stays
    a claim-path statistic."""
    cache = FeatureCache(1 << 20)
    cache.put(_key(0), _batch(0))
    status, batch = cache.begin(_key(0), prewarm=True)
    assert status == "hit"
    np.testing.assert_array_equal(batch["labels"], _batch(0)["labels"])
    cs = cache.stats()
    assert cs.prewarm_hits == 1 and cs.hits == 0 and cs.misses == 0
    # cold key: the pre-warmer takes the leader lease without a miss
    status, val = cache.begin(_key(1), prewarm=True)
    assert status == "produce" and val is None
    cs = cache.stats()
    assert cs.prewarm_leases == 1 and cs.misses == 0
    # a concurrent tenant's CLAIM follows the pre-warm lease; fulfill
    # resolves it bitwise
    status, fut = cache.begin(_key(1))
    assert status == "follow"
    cache.fulfill(_key(1), _batch(1))
    np.testing.assert_array_equal(
        fut.result(timeout=1)["labels"], _batch(1)["labels"])
    assert cache.stats().follows == 1
    # the claim landing on the pre-warmed content still counts ITSELF
    assert cache.begin(_key(0))[0] == "hit"
    assert cache.stats().hits == 1


def test_prewarm_spill_hit_promotes_without_hit_accounting():
    """A pre-warm probe on a spilled entry promotes it into the memory tier
    (that is the point: the claim arrives to a memory hit) but books the
    spill read under prewarm_hits, not hits/spill_hits."""
    spill = CacheSpillStore(num_devices=2, bytes_per_s=1e6)
    cache = FeatureCache(capacity_bytes=2 * batch_nbytes(_batch(0)), spill=spill)
    for i in range(4):
        cache.put(_key(i), _batch(i))  # 0 and 1 spill out
    status, block = cache.begin(_key(0), prewarm=True)
    assert status == "hit"
    np.testing.assert_array_equal(block["labels"], _batch(0)["labels"])
    cs = cache.stats()
    assert cs.prewarm_hits == 1 and cs.hits == 0 and cs.spill_hits == 0
    # promoted: the real claim that follows is a memory-tier hit
    assert cache.begin(_key(0))[0] == "hit"
    cs = cache.stats()
    assert cs.hits == 1 and cs.spill_hits == 0


def test_service_prewarms_ahead_of_claims_bitwise(rm1):
    """Mixed cold/cached content: the peek-window walker pre-warms the
    cached back half while the front half still produces cold — batches
    stay bitwise identical to cold compute and the pre-warm leases are
    consumed by the session's own claims (no self-follow deadlock)."""
    rcfg, src, spec, store, engine = rm1
    solo = {pid: engine.produce_batch(store, pid) for pid in range(12)}
    cache = FeatureCache(256 << 20)
    with PreprocessingService(num_workers=1, cache=cache) as svc:
        svc.submit(JobSpec(name="seed", partitions=range(6, 12), engine=engine,
                           store=store, units=1)).drain()
        session = svc.submit(JobSpec(
            name="walk", partitions=range(12), engine=engine, store=store,
            units=1, queue_depth=12, lookahead=4, megabatch=2))
        got = {pid: mb for pid, mb in session}
        st = session.stats()
    for pid in range(12):
        for k in solo[pid]:
            np.testing.assert_array_equal(
                np.asarray(solo[pid][k]), np.asarray(got[pid][k]),
                err_msg=f"pid={pid} key={k} diverged through pre-warm")
    assert st.done and sorted(got) == list(range(12))
    assert st.prewarm_hits > 0  # the walker reached the cached back half
    assert cache.stats().prewarm_hits >= st.prewarm_hits


def test_prewarm_off_keeps_lookahead_window(rm1):
    """prewarm=False: the staging window still runs, no pre-warm probes are
    issued, and delivery stays complete and bitwise."""
    rcfg, src, spec, store, engine = rm1
    solo = {pid: engine.produce_batch(store, pid) for pid in range(12)}
    cache = FeatureCache(256 << 20)
    with PreprocessingService(num_workers=1, cache=cache) as svc:
        session = svc.submit(JobSpec(
            name="nowarm", partitions=range(12), engine=engine, store=store,
            units=1, queue_depth=12, lookahead=4, prewarm=False))
        got = {pid: mb for pid, mb in session}
        st = session.stats()
    for pid in range(12):
        for k in solo[pid]:
            np.testing.assert_array_equal(
                np.asarray(solo[pid][k]), np.asarray(got[pid][k]),
                err_msg=f"pid={pid} key={k}")
    assert st.prewarm_hits == 0 and cache.stats().prewarm_hits == 0
