"""Elastic control plane: kill/join, checkpoint/resume, autoscaling, events.

The acceptance invariants (ISSUE 7):

* killing any single pool worker mid-session yields batches bitwise
  identical to a no-failure run (the dead worker's claims re-issue through
  the straggler path), across pipeline / autotune / cache-on modes;
* restarting the whole service from a ``SessionCheckpoint`` resumes a
  half-drained job bitwise-identically;
* the autoscaler grows the pool under a backlogged multi-tenant load and
  shrinks it when drained;
* every membership / scale / re-issue decision is visible in the
  structured event stream via ``events`` and ``stats()``.
"""

import threading
import time

import numpy as np
import pytest

from repro.configs.registry import get_recsys
from repro.core.ctrlplane import (
    Autoscaler,
    AutoscalePolicy,
    EventLog,
    FailureInjector,
    SessionCheckpoint,
    SimulatedFailure,
    parse_kill_spec,
)
from repro.core.featcache import FeatureCache
from repro.core.presto import PreStoEngine
from repro.core.service import JobSpec, PreprocessingService
from repro.core.spec import TransformSpec
from repro.data.loader import WorkQueue
from repro.data.storage import PartitionedStore
from repro.data.synth import SyntheticRecSysSource

N_PARTS = 10

# the three produce-path modes the bitwise invariants must hold across
MODES = {
    "pipeline": dict(megabatch=2),
    "autotune": dict(autotune=True, lookahead=2),
    "cache": dict(megabatch=2),
}


@pytest.fixture(scope="module")
def rm1():
    rcfg = get_recsys("rm1", reduced=True)
    src = SyntheticRecSysSource(rcfg.data, rows=192)
    spec = TransformSpec.from_source(src)
    engine = PreStoEngine(spec)  # one jit cache across every run here
    ref_store = PartitionedStore(N_PARTS, num_devices=4, source=src)
    # the no-failure ground truth every chaos run must match bitwise
    ref = {pid: engine.produce_batch(ref_store, pid) for pid in range(N_PARTS)}
    return {"src": src, "spec": spec, "engine": engine, "ref": ref}


def _assert_bitwise(got: dict, ref: dict) -> None:
    assert sorted(got) == sorted(ref)
    for pid, batch in got.items():
        want = ref[pid]
        assert sorted(batch) == sorted(want)
        for key in want:
            np.testing.assert_array_equal(
                np.asarray(batch[key]), np.asarray(want[key])
            )


# -- event stream --------------------------------------------------------------


def test_eventlog_bounded_ring_counts_and_cursor(tmp_path):
    log = EventLog(capacity=4)
    for i in range(10):
        log.emit("tick", i=i)
    log.emit("other")
    assert log.emitted == 11
    counts = log.counts()
    assert counts == {"tick": 10, "other": 1}  # all-time, not ring-bounded
    tail = log.tail(2)
    assert [e.kind for e in tail] == ["tick", "other"]
    assert tail[0].data == {"i": 9}
    assert [e.kind for e in log.tail(10, kind="tick")] == ["tick"] * 3
    # the incremental cursor: strictly-greater seq, dropped prefix absent
    assert [e.seq for e in log.since(8)] == [9, 10]
    assert log.since(10) == []
    summ = log.summary(tail=2)
    assert summ["emitted"] == 11 and summ["dropped"] == 7
    assert [e["kind"] for e in summ["tail"]] == ["tick", "other"]
    out = tmp_path / "events.json"
    log.dump(str(out))
    import json

    assert [e["seq"] for e in json.loads(out.read_text())] == [7, 8, 9, 10]


def test_workqueue_expire_reissues_immediately():
    seen = []
    q = WorkQueue([0, 1], straggler_timeout=60.0, on_reissue=seen.append)
    assert q.claim() == 0
    assert q.expire(0) is True  # the crash hook: overdue NOW, no timeout wait
    assert q.claim() == 1  # fresh claims still drain first
    assert q.claim() == 0 and q.reissues == 1 and seen == [0]
    assert q.expire(7) is False  # unknown pid: no-op
    q.complete(0)
    assert q.expire(0) is False  # completed pid: no-op, result already won


def test_failure_injector_and_kill_spec():
    log = EventLog()
    inj = FailureInjector(fail_at=3, events=log)
    inj.check(0)
    with pytest.raises(SimulatedFailure, match="simulated failure at step 3"):
        inj.check(3)
    inj.check(3)  # fires at most once: the restarted run sails past
    assert inj.fired and log.counts() == {"failure_injected": 1}
    assert FailureInjector(fail_at=None).check(0) is None
    assert parse_kill_spec("2@15") == (15, 2)
    with pytest.raises(ValueError):
        parse_kill_spec("2:15")


# -- kill mid-flight: bitwise identical completion ------------------------------


class _GatedStore(PartitionedStore):
    """Blocks the FIRST reader of ``gate_pid`` until released, recording the
    reading thread's name — a deterministic mid-flight kill point."""

    def __init__(self, *args, gate_pid: int = 0, **kwargs):
        super().__init__(*args, **kwargs)
        self.gate_pid = gate_pid
        self.caught = threading.Event()
        self.release = threading.Event()
        self.holder = None
        self._gate_lock = threading.Lock()

    def read(self, partition_id: int):
        hold = False
        with self._gate_lock:
            if partition_id == self.gate_pid and not self.caught.is_set():
                self.holder = threading.current_thread().name
                self.caught.set()
                hold = True
        if hold:
            assert self.release.wait(timeout=30)
        return super().read(partition_id)


@pytest.mark.parametrize("mode", sorted(MODES))
def test_kill_worker_mid_flight_is_bitwise_identical(rm1, mode, tmp_path):
    store = _GatedStore(N_PARTS, num_devices=4, source=rm1["src"])
    cache = FeatureCache(256 << 20) if mode == "cache" else None
    svc = PreprocessingService(num_workers=3, cache=cache)
    try:
        job = JobSpec(
            name=f"chaos-{mode}", partitions=range(N_PARTS),
            engine=rm1["engine"], store=store, units=3,
            straggler_timeout=60.0,  # re-issue must come from the kill, not time
            use_cache=(mode == "cache"), **MODES[mode],
        )
        sess = svc.submit(job)
        assert store.caught.wait(timeout=30)  # a worker is mid-read of pid 0
        assert store.holder.startswith("presto-pool-")
        wid = int(store.holder.rsplit("-", 1)[1])
        assert svc.kill_worker(wid) is True
        assert svc.num_workers == 2  # capacity re-planned immediately
        store.release.set()  # the dead worker wakes only to abandon its work
        got = {pid: mb for pid, mb in sess}
    finally:
        store.release.set()
        svc.close()
    _assert_bitwise(got, rm1["ref"])
    st = sess.stats()
    assert st.done and not st.cancelled
    assert st.reissues >= 1  # the dead worker's claims went back through
    counts = svc.events.counts()
    assert counts.get("worker_leave") == 1
    assert counts.get("claim_reissue", 0) >= 1
    # the same stream is surfaced through stats()
    assert svc.stats()["events"]["counts"] == counts


def test_kill_below_admission_floor_degrades_not_evicts():
    """Two admitted tenants on two workers; a crash to one worker is below
    the admission floor — the degraded plan keeps both sessions live (1-unit
    floor shares, pass 2 stays work-conserving) and both finish."""
    gate = threading.Event()

    def produce(pid):
        gate.wait(timeout=10)
        return {"labels": np.full((4,), pid)}

    svc = PreprocessingService(num_workers=2)
    try:
        s1 = svc.submit(JobSpec(name="a", partitions=range(6),
                                produce_fn=produce, use_cache=False))
        s2 = svc.submit(JobSpec(name="b", partitions=range(6),
                                produce_fn=produce, use_cache=False))
        wid = next(iter(svc._workers))
        assert svc.kill_worker(wid)
        assert svc.num_workers == 1
        gate.set()
        got1 = {pid for pid, _ in s1}
        got2 = {pid for pid, _ in s2}
    finally:
        gate.set()
        svc.close()
    assert got1 == got2 == set(range(6))
    assert s1.stats().done and s2.stats().done


# -- checkpoint / restart / resume ----------------------------------------------


@pytest.mark.parametrize("mode", sorted(MODES))
def test_service_restart_resumes_bitwise_from_checkpoint(rm1, mode, tmp_path):
    src = rm1["src"]
    ckpt = tmp_path / f"frontier-{mode}.json"
    cache = FeatureCache(256 << 20) if mode == "cache" else None
    job = JobSpec(
        name=f"resume-{mode}", partitions=range(N_PARTS),
        engine=rm1["engine"],
        store=PartitionedStore(N_PARTS, num_devices=4, source=src),
        units=2, use_cache=(mode == "cache"),
        checkpoint_path=str(ckpt), checkpoint_every=2, **MODES[mode],
    )

    # incarnation 1: deliver 4 batches, then the whole service dies
    svc1 = PreprocessingService(num_workers=2, cache=cache)
    got = {}
    it = iter(svc1.submit(job))
    for _ in range(4):
        pid, mb = next(it)
        got[pid] = mb
    assert svc1.events.counts().get("checkpoint", 0) >= 1
    svc1.close()

    # incarnation 2: resume from the on-disk frontier (4 delivered)
    ck = SessionCheckpoint.load(str(ckpt))
    assert ck.job == job.name and len(ck.delivered) == 4
    assert ck.remaining() == [p for p in range(N_PARTS) if p not in got]
    assert ck.to_dict() == SessionCheckpoint.from_dict(ck.to_dict()).to_dict()
    svc2 = PreprocessingService(num_workers=2, cache=cache)
    try:
        sess2 = svc2.submit(job, resume_from=ck)
        assert sess2.total == N_PARTS - 4  # only the remainder is re-run
        for pid, mb in sess2:
            assert pid not in got  # delivered frontier is never re-delivered
            got[pid] = mb
    finally:
        svc2.close()
    _assert_bitwise(got, rm1["ref"])
    assert sess2.stats().done
    counts = svc2.events.counts()
    assert counts.get("resume") == 1 and counts.get("session_join") == 1
    if mode == "autotune":
        # the tuner state rode the checkpoint: resumed session starts at the
        # checkpointed rung instead of re-climbing from the seed
        assert ck.tuner is not None


def test_checkpoint_rejects_foreign_job(rm1):
    ck = SessionCheckpoint(job="x", partitions=[0, 1], delivered=[0])
    with pytest.raises(ValueError, match="checkpoint is for job"):
        ck.apply(JobSpec(name="y", partitions=[0, 1], produce_fn=lambda p: p))
    assert ck.fraction_done == 0.5


# -- autoscaling ----------------------------------------------------------------


def test_autoscaler_grows_under_backlog_and_shrinks_when_drained():
    hold = threading.Event()

    def produce(pid):
        hold.wait(timeout=30)  # deterministic backlog: nothing drains yet
        return {"labels": np.full((4,), pid)}

    svc = PreprocessingService(num_workers=2)
    scaler = Autoscaler(svc, AutoscalePolicy(
        min_workers=1, max_workers=4, backlog_per_worker=2.0))
    try:
        s1 = svc.submit(JobSpec(name="t1", partitions=range(12),
                                produce_fn=produce, units=3, use_cache=False))
        s2 = svc.submit(JobSpec(name="t2", partitions=range(12),
                                produce_fn=produce, units=3, use_cache=False))
        snap = svc.load_snapshot()
        assert snap["backlog"] == 24 and snap["workers"] == 2
        assert scaler.desired(snap) == 4  # backlog-capped want, bound-clamped
        # max_step=1: the pool grows one worker per evaluation
        for want in (3, 4):
            assert scaler.step() == 1 and svc.num_workers == want
        assert scaler.step() == 0  # at the bound: no further growth
        hold.set()
        s1.drain()
        s2.drain()
        deadline = time.monotonic() + 10
        while svc.load_snapshot()["sessions"] and time.monotonic() < deadline:
            time.sleep(0.01)  # retire is on the worker path; give it a beat
        while scaler.step() < 0:
            pass
        assert svc.num_workers == 1  # drained: back to the floor
    finally:
        hold.set()
        scaler.stop()
        svc.close()
    counts = svc.events.counts()
    assert counts.get("scale_up") == 2 and counts.get("worker_join") == 2
    assert counts.get("scale_down") == 3 and counts.get("worker_leave") == 3
    ups = svc.events.tail(50, kind="scale_up")
    assert all(e.data["backlog"] > 0 and e.data["target"] == 4 for e in ups)


def test_remove_worker_respects_admission_floor():
    svc = PreprocessingService(num_workers=2)
    try:
        gate = threading.Event()

        def produce(pid):
            gate.wait(timeout=10)
            return pid

        s1 = svc.submit(JobSpec(name="f1", partitions=range(3),
                                produce_fn=produce, use_cache=False))
        s2 = svc.submit(JobSpec(name="f2", partitions=range(3),
                                produce_fn=produce, use_cache=False))
        assert svc.remove_worker() is None  # 2 sessions need 2 units
        gate.set()
        s1.drain()
        s2.drain()
        deadline = time.monotonic() + 10
        while svc.load_snapshot()["sessions"] and time.monotonic() < deadline:
            time.sleep(0.01)
        assert svc.remove_worker() is not None  # drained: shrink allowed
        assert svc.num_workers == 1
        assert svc.remove_worker() is None  # never below one worker
    finally:
        svc.close()


# -- membership + topology -------------------------------------------------------


def test_kill_and_join_replan_device_topology(rm1):
    svc = PreprocessingService(num_workers=3, devices=3)
    try:
        assert svc._topology.units_per_device == {0: 1, 1: 1, 2: 1}
        dev_of = {w.wid: w.device for w in svc._workers.values()}
        victim = next(w for w, d in dev_of.items() if d == 2)
        assert svc.kill_worker(victim)
        assert svc._topology.units_per_device == {0: 1, 1: 1, 2: 0}
        assert svc._manned == {0, 1}  # device 2 lost its unit: host fallback
        wid = svc.add_worker()  # least-manned binding: straight back to dev 2
        assert svc._workers[wid].device == 2
        assert svc._topology.units_per_device == {0: 1, 1: 1, 2: 1}
        sess = svc.submit(JobSpec(name="topo", partitions=range(6),
                                  produce_fn=lambda p: p, use_cache=False))
        assert sorted(pid for pid, _ in sess) == list(range(6))
    finally:
        svc.close()
    counts = svc.events.counts()
    assert counts.get("worker_leave") == 1 and counts.get("worker_join") == 1
    leave = svc.events.tail(50, kind="worker_leave")[0]
    assert leave.data["reason"] == "killed" and leave.data["device"] == 2


def test_add_worker_mid_session_speeds_completion(rm1):
    """Joining workers pick up a live session's remaining claims."""
    svc = PreprocessingService(num_workers=1)
    try:
        started = threading.Event()

        def produce(pid):
            started.set()
            time.sleep(0.005)
            return {"labels": np.full((2,), pid)}

        sess = svc.submit(JobSpec(name="grow", partitions=range(16),
                                  produce_fn=produce, use_cache=False))
        assert started.wait(timeout=10)
        for _ in range(3):
            svc.add_worker()
        assert svc.num_workers == 4
        got = {pid for pid, _ in sess}
    finally:
        svc.close()
    assert got == set(range(16)) and sess.stats().done
    assert svc.events.counts().get("worker_join") == 3


# -- seeded chaos matrix (ISSUE 8) ---------------------------------------------
# The kill/join schedule is DERIVED from a seed, the schedule runs against
# every produce-path mode, and the invariant is two-layered: the threaded
# service must deliver bitwise-identical batches no matter where the chaos
# lands, and the virtual-time twin of the same seeded schedule must replay
# a byte-identical event trace (threads cannot promise trace equality —
# the sim clock is what makes the trace itself deterministic).


def _sim_chaos_trace(seed: int) -> bytes:
    """One seeded chaos schedule under the sim clock -> its trace bytes."""
    from repro.core.simclock import SimHarness

    h = SimHarness(seed=seed, num_workers=3, num_devices=2,
                   straggler_timeout=0.05)
    h.workload(24, arrival_window_s=0.4)
    sched = np.random.default_rng(seed)
    for _ in range(2):
        h.kill_at(float(sched.uniform(0.01, 0.25)), int(sched.integers(0, 3)))
    h.join_at(float(sched.uniform(0.25, 0.4)))
    h.run()
    return h.trace_bytes()


@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize("seed", [1, 2])
def test_seeded_chaos_matrix_bitwise_and_replayable(rm1, mode, seed):
    rng = np.random.default_rng(seed)
    kill_after, kill_slot = int(rng.integers(1, 4)), int(rng.integers(0, 3))
    rejoin = bool(rng.integers(0, 2))

    cache = FeatureCache(256 << 20) if mode == "cache" else None
    svc = PreprocessingService(num_workers=3, cache=cache)
    got = {}
    try:
        sess = svc.submit(JobSpec(
            name=f"chaos-{mode}-{seed}", partitions=range(N_PARTS),
            engine=rm1["engine"],
            store=PartitionedStore(N_PARTS, num_devices=4, source=rm1["src"]),
            units=3, straggler_timeout=60.0,
            use_cache=(mode == "cache"), **MODES[mode],
        ))
        it = iter(sess)
        for _ in range(kill_after):  # seeded kill point in delivery order
            pid, mb = next(it)
            got[pid] = mb
        wid = sorted(svc._workers)[kill_slot % len(svc._workers)]
        assert svc.kill_worker(wid) is True
        if rejoin:
            svc.add_worker()
        for pid, mb in it:
            got[pid] = mb
    finally:
        svc.close()
    _assert_bitwise(got, rm1["ref"])
    assert sess.stats().done
    assert svc.events.counts().get("worker_leave") == 1

    # the virtual-time twin: the SAME seed replays byte-identically
    assert _sim_chaos_trace(seed) == _sim_chaos_trace(seed)


def test_sim_chaos_traces_differ_across_seeds():
    assert _sim_chaos_trace(1) != _sim_chaos_trace(2)


# -- checkpoint/resume edge cases (ISSUE 8) ------------------------------------


def test_checkpoint_at_delivery_zero_resumes_full_job(rm1):
    """A frontier snapshotted before ANY delivery resumes the whole job."""
    job = JobSpec(
        name="zero", partitions=range(N_PARTS), engine=rm1["engine"],
        store=PartitionedStore(N_PARTS, num_devices=4, source=rm1["src"]),
        units=2,
    )
    svc1 = PreprocessingService(num_workers=2)
    sess1 = svc1.submit(job)
    ck = sess1.checkpoint()  # delivery 0: nothing has reached the consumer
    svc1.close()
    assert ck.delivered == [] and ck.fraction_done == 0.0
    assert ck.remaining() == list(range(N_PARTS))

    svc2 = PreprocessingService(num_workers=2)
    try:
        sess2 = svc2.submit(job, resume_from=ck)
        assert sess2.total == N_PARTS
        got = {pid: mb for pid, mb in sess2}
    finally:
        svc2.close()
    _assert_bitwise(got, rm1["ref"])


def test_checkpoint_after_final_partition_resumes_to_noop(rm1, tmp_path):
    """The completion checkpoint (written after the final delivery) resumes
    an already-complete session: zero remaining work, an immediately-done
    empty stream, no re-delivery."""
    ckpt = tmp_path / "final.json"
    job = JobSpec(
        name="final", partitions=range(N_PARTS), engine=rm1["engine"],
        store=PartitionedStore(N_PARTS, num_devices=4, source=rm1["src"]),
        units=2, checkpoint_path=str(ckpt), checkpoint_every=4,
    )
    svc1 = PreprocessingService(num_workers=2)
    try:
        got = {pid: mb for pid, mb in svc1.submit(job)}
    finally:
        svc1.close()
    _assert_bitwise(got, rm1["ref"])

    ck = SessionCheckpoint.load(str(ckpt))
    assert ck.fraction_done == 1.0 and ck.remaining() == []
    assert sorted(ck.delivered) == list(range(N_PARTS))

    svc2 = PreprocessingService(num_workers=2)
    try:
        sess2 = svc2.submit(job, resume_from=ck)
        assert sess2.total == 0
        assert list(sess2) == []  # nothing re-delivered, stream just ends
        assert sess2.stats().done and not sess2.stats().cancelled
    finally:
        svc2.close()


def test_resume_with_stale_cache_root_still_bitwise(rm1):
    """Resuming a cache-mode job into a service whose feature cache is a
    fresh (stale-rooted) instance: every hit the first incarnation banked is
    gone, so the resume must re-produce — and stay bitwise identical."""
    job = JobSpec(
        name="stale-cache", partitions=range(N_PARTS), engine=rm1["engine"],
        store=PartitionedStore(N_PARTS, num_devices=4, source=rm1["src"]),
        units=2, use_cache=True, megabatch=2,
    )
    svc1 = PreprocessingService(num_workers=2, cache=FeatureCache(256 << 20))
    got = {}
    it1 = iter(svc1.submit(job))
    for _ in range(N_PARTS // 2):
        pid, mb = next(it1)
        got[pid] = mb
    ck = SessionCheckpoint(
        job=job.name, partitions=list(range(N_PARTS)),
        delivered=sorted(got),
    )
    svc1.close()

    # brand-new cache: the old root's contents are unreachable (stale)
    svc2 = PreprocessingService(num_workers=2, cache=FeatureCache(256 << 20))
    try:
        sess2 = svc2.submit(job, resume_from=ck)
        for pid, mb in sess2:
            assert pid not in got
            got[pid] = mb
    finally:
        svc2.close()
    _assert_bitwise(got, rm1["ref"])
    st = sess2.stats()
    assert st.done and st.cache_hits == 0  # nothing survived the stale root
